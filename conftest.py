"""Repo-root pytest bootstrap: make `python -m pytest` work without an
explicit PYTHONPATH=src (the tier-1 command still sets it; CI and bare
local runs get it for free)."""

import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

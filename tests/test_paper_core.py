"""Validation of the DeepNVM++ reproduction against the paper's numbers."""


import pytest

from repro.core import bitcell, isoarea, isocap, scaling, tuner
from repro.core.calibration import PAPER_CLAIMS, TABLE1, TABLE2


class TestTable1:
    def test_fin_counts_derived_by_sweep(self):
        stt = bitcell.characterize("stt")
        sot = bitcell.characterize("sot")
        assert (stt.fins_read, stt.fins_write) == (4, 4)
        assert (sot.fins_read, sot.fins_write) == (1, 3)

    @pytest.mark.parametrize("mem", ["stt", "sot"])
    def test_device_parameters(self, mem):
        c = bitcell.characterize(mem)
        ref = TABLE1[mem]
        assert c.sense_latency_s == pytest.approx(ref["sense_lat"], rel=0.02)
        assert c.sense_energy_j == pytest.approx(ref["sense_e"], rel=0.02)
        assert c.write_latency_set_s == pytest.approx(ref["wlat_set"], rel=0.02)
        assert c.write_latency_reset_s == pytest.approx(ref["wlat_reset"],
                                                        rel=0.02)
        assert c.write_energy_set_j == pytest.approx(ref["we_set"], rel=0.05)
        assert c.write_energy_reset_j == pytest.approx(ref["we_reset"], rel=0.05)
        assert c.area_norm == pytest.approx(ref["area"], rel=0.01)

    def test_sram_is_area_baseline(self):
        assert bitcell.characterize("sram").area_norm == 1.0


class TestTable2:
    @pytest.mark.parametrize("mem", ["sram", "stt", "sot"])
    def test_3mb_anchor_exact(self, mem):
        d = tuner.tuned_design(mem, 3)
        ref = TABLE2[mem]
        assert d.read_latency_s * 1e9 == pytest.approx(ref["rlat"], rel=0.01)
        assert d.write_latency_s * 1e9 == pytest.approx(ref["wlat"], rel=0.01)
        assert d.read_energy_j * 1e9 == pytest.approx(ref["re"], rel=0.01)
        assert d.write_energy_j * 1e9 == pytest.approx(ref["we"], rel=0.01)
        assert d.leakage_w * 1e3 == pytest.approx(ref["leak"], rel=0.01)
        assert d.area_mm2 == pytest.approx(ref["area"], rel=0.01)

    def test_iso_area_capacities(self):
        assert tuner.iso_area_capacity("stt") == 7
        assert tuner.iso_area_capacity("sot") == 10

    def test_iso_area_ppa_within_model_tolerance(self):
        # latency/energy at the iso-area points are model extrapolation;
        # leak/area are anchored (see EXPERIMENTS.md SSValidation)
        for col in ("stt_isoarea", "sot_isoarea"):
            d = tuner.tuned_design(col.split("_")[0], TABLE2[col]["cap"])
            assert d.leakage_w * 1e3 == pytest.approx(TABLE2[col]["leak"],
                                                      rel=0.01)
            assert d.area_mm2 == pytest.approx(TABLE2[col]["area"], rel=0.01)
            assert d.read_latency_s * 1e9 == pytest.approx(
                TABLE2[col]["rlat"], rel=0.40)

    def test_edap_tuning_beats_median_of_space(self):
        """Algorithm 1 must pick a design no worse than the space median."""
        from repro.core.cachemodel import CacheModel
        model = CacheModel("stt")
        cap = 3 * 2**20
        edaps = sorted(model.evaluate(cap, org).edap()
                       for org in model.design_space(cap))
        tuned = tuner.tune(model, cap)
        assert tuned.edap() <= edaps[len(edaps) // 2]
        assert tuned.edap() == pytest.approx(edaps[0])


class TestHeadlineClaims:
    @pytest.fixture(scope="class")
    def isocap_summary(self):
        return isocap.summary(isocap.analyze())

    def test_dyn_energy(self, isocap_summary):
        for mem in ("stt", "sot"):
            paper = PAPER_CLAIMS["isocap_dyn_energy_x"][mem]
            assert isocap_summary[mem]["dyn_energy_x"] == pytest.approx(
                paper, rel=0.15)

    def test_leak_reduction(self, isocap_summary):
        for mem in ("stt", "sot"):
            paper = PAPER_CLAIMS["isocap_leak_reduction"][mem]
            assert isocap_summary[mem]["leak_reduction"] == pytest.approx(
                paper, rel=0.15)

    def test_energy_reduction_direction_and_band(self, isocap_summary):
        # model reconstruction runs ~20% below the paper's means (see
        # EXPERIMENTS.md); the ordering SOT > STT >> 1 must hold
        stt = isocap_summary["stt"]["energy_reduction"]
        sot = isocap_summary["sot"]["energy_reduction"]
        assert sot > stt > 3.0
        assert sot == pytest.approx(
            PAPER_CLAIMS["isocap_energy_reduction"]["sot"], rel=0.25)

    def test_read_share(self, isocap_summary):
        assert isocap_summary["sram"]["read_share_of_dyn"] == pytest.approx(
            PAPER_CLAIMS["sram_read_share_of_dyn"], abs=0.1)

    def test_fig6_dram_anchors(self):
        curve = isoarea.dram_reduction_curve()
        assert curve[7] == pytest.approx(14.6, abs=2.0)
        assert curve[10] == pytest.approx(19.8, abs=2.0)
        # monotone saturating curve like the paper's
        caps = sorted(curve)
        assert all(curve[a] <= curve[b] + 1e-9
                   for a, b in zip(caps, caps[1:]))

    def test_isoarea_energy_reduction(self):
        s = isoarea.summary(isoarea.analyze())
        assert s["stt"]["energy_reduction"] == pytest.approx(
            PAPER_CLAIMS["isoarea_energy_reduction"]["stt"], rel=0.15)
        assert s["sot"]["edp_reduction_with_dram"] == pytest.approx(
            PAPER_CLAIMS["isoarea_edp_reduction_with_dram"]["sot"], rel=0.15)

    def test_scaling_orders_of_magnitude(self):
        head = scaling.headline(scaling.workload_sweep(
            capacities_mb=(1, 4, 16, 32)))
        # the paper's qualitative claim: EDP reduction reaches orders of
        # magnitude at large capacities for both flavors
        assert head["stt"]["edp_reduction_max"] > 10
        assert head["sot"]["edp_reduction_max"] > 30

    def test_scaling_sram_wins_small_caps(self):
        rows = scaling.workload_sweep(capacities_mb=(1,))
        # at 1 MB, SRAM EDP is competitive (ratio ~1 or better for STT)
        stt = [r for r in rows if r.mem == "stt"]
        assert all(r.edp_x > 0.7 for r in stt)


class TestCrossoverStructure:
    """Fig. 9 qualitative structure."""

    def test_read_latency_crossover(self):
        r1 = {m: tuner.tuned_design(m, 1).read_latency_s
              for m in ("sram", "stt")}
        r16 = {m: tuner.tuned_design(m, 16).read_latency_s
               for m in ("sram", "stt")}
        assert r1["sram"] < r1["stt"]     # SRAM faster at small caps
        assert r16["sram"] > r16["stt"]   # MRAM faster at large caps

    def test_leakage_gap_grows(self):
        gap = [tuner.tuned_design("sram", c).leakage_w
               / tuner.tuned_design("sot", c).leakage_w for c in (2, 8, 32)]
        assert gap[0] < gap[1] < gap[2]

    def test_area_reduction_matches_paper_average(self):
        s = tuner.tuned_design("sram", 3).area_mm2
        assert 1 - tuner.tuned_design("stt", 3).area_mm2 / s == \
            pytest.approx(0.58, abs=0.03)
        assert 1 - tuner.tuned_design("sot", 3).area_mm2 / s == \
            pytest.approx(0.65, abs=0.03)

"""Kernel correctness: Pallas (interpret mode) + flash ref vs jnp oracles,
swept over shapes/dtypes per the deliverable spec."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6 import wkv6

SHAPES = [  # (B, S, H, hd)
    (1, 128, 1, 64),
    (2, 256, 4, 64),
    (1, 512, 2, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _qkv(shape, dtype, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    return tuple(jax.random.normal(k, shape, jnp.float32).astype(dtype)
                 for k in ks)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 128)])
def test_flash_pallas_vs_oracle(shape, dtype, causal, window):
    q, k, v = _qkv(shape, dtype)
    want = ref.naive_attention(q, k, v, causal=causal, window=window)
    got = flash_attention(q, k, v, block_q=64, block_k=64, causal=causal,
                          window=window, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", SHAPES)
def test_flash_ref_fwd_and_grads(shape):
    q, k, v = _qkv(shape, jnp.float32)

    def loss_naive(q, k, v):
        return jnp.sum(ref.naive_attention(q, k, v, causal=True,
                                           window=None) ** 2)

    def loss_flash(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, 64, True, None,
                                               0, None) ** 2)

    g1 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-4)


def test_flash_ref_shared_kv_mla_layout():
    """MLA latent attention: single shared kv head, v dim != qk dim."""
    b, s, h = 2, 256, 4
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, 96))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, 1, 96))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, 1, 48))
    kx = jnp.broadcast_to(k, (b, s, h, 96))
    vx = jnp.broadcast_to(v, (b, s, h, 48))
    want = ref.naive_attention(q, kx, vx, causal=True, window=None,
                               scale=96 ** -0.5)
    got = ref.flash_attention_ref(q, k, v, 64, True, None, 0, 96 ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("shape", [(1, 128, 2, 32), (2, 256, 4, 64)])
@pytest.mark.parametrize("chunk", [32, 64])
def test_wkv6_pallas_vs_oracle(shape, chunk):
    b, s, h, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(kk, shape) * 0.5 for kk in ks[:3])
    w = jnp.exp(-jnp.exp(-3.0 + 0.5 * jax.random.normal(ks[3], shape)))
    u = jax.random.normal(ks[4], (h, hd)) * 0.1
    want, _ = ref.wkv6_ref(r, k, v, w, u)
    got = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_state_carry_composition():
    """ref oracle: running two halves with the carried state == one run."""
    shape = (1, 128, 2, 32)
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r, k, v = (jax.random.normal(kk, shape) * 0.5 for kk in ks[:3])
    w = jnp.exp(-jnp.exp(-3.0 + 0.5 * jax.random.normal(ks[3], shape)))
    u = jax.random.normal(ks[4], (2, 32)) * 0.1
    y_all, s_all = ref.wkv6_ref(r, k, v, w, u)
    y1, s1 = ref.wkv6_ref(r[:, :64], k[:, :64], v[:, :64], w[:, :64], u)
    y2, s2 = ref.wkv6_ref(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:], u,
                          s0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               rtol=1e-5, atol=1e-5)


def test_flash_q_offset_decode_window():
    """q_offset positions queries for chunked prefill continuation."""
    b, s, h, hd = 1, 256, 2, 64
    q, k, v = _qkv((b, s, h, hd), jnp.float32, key=5)
    full = ref.naive_attention(q, k, v, causal=True, window=None)
    # second half of queries, with q_offset, against full kv
    half = ref.flash_attention_ref(q[:, 128:], k, v, 64, True, None,
                                   128, None)
    np.testing.assert_allclose(np.asarray(half), np.asarray(full[:, 128:]),
                               rtol=1e-5, atol=1e-5)

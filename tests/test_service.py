"""Tests for the concurrent sweep service (repro/sweep/service.py) and
the LM ``@b<n>`` scenario namespace that rides with it.

Families:

  coalesce   seeded property test: N concurrent compatible specs through
             the coalescing window match their individual ``run()``
             results at <= 1e-12, delivered exactly once; incompatible
             platform axes pass through as separate evaluations;
  cache      result cache hits/misses, canonical-key stability, bounded
             eviction;
  lifecycle  graceful shutdown drains a slow in-flight request and the
             coalescing window before the worker stops; handle() after
             close answers with an error document;
  transport  HTTP (ephemeral port) and unix-socket servers speak the
             same handler as stdin; subprocess SIGTERM exits 0 with
             --stats-on-exit output after answering real traffic;
  stats      the {"op": "stats"} document: request counters, cache and
             coalesce counters, cells/elapsed_ms percentiles;
  backpressure  oversize documents answer 413 (HTTP refuses before
             reading the body), the bounded admission gate answers 429
             with cache hits and ops exempt, and both are counted in
             stats()["limits"];
  lm         lm/<arch>/<shape>@b<n> resolution, inverse, registry names,
             and end-to-end service evaluation of batch-override cells.
"""

import json
import os
import random
import signal
import subprocess
import sys
import threading
import time

import pytest

from repro import scenarios
from repro.core import sweep
from repro.core.sweep import SymbolicSweepSpec, spec_union
from repro.sweep import client
from repro.sweep import service as service_mod
from repro.sweep.service import (
    Coalescer,
    ResultCache,
    SweepService,
    evaluate_spec,
    spec_key,
)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# A small scenario/design pool so the whole module compiles a handful of
# bucketed fold shapes at most (shapes are shared across tests).
SCENARIOS = ("cnn/alexnet/infer@b4", "cnn/alexnet/train@b64",
             "cnn/squeezenet/infer@b4", "cnn/resnet18/train@b64")
CAPS = ("3MB", "8MB")


def designs_at(caps=("3MB",)):
    # full mem triple per capacity so every spec carries its own baseline
    return [f"{m}@{c}" for c in caps for m in ("sram", "stt", "sot")]


def doc(name, scens=SCENARIOS[:2], designs=None, platforms=("gtx-1080ti",)):
    return {"schema": "deepnvm.sweepspec/2", "name": name,
            "scenarios": list(scens),
            "designs": list(designs or designs_at()),
            "platforms": list(platforms), "baseline_mem": "sram"}


def assert_doc_close(got, want, tol=1e-12):
    """Recursive numeric comparison for nested summary documents."""
    if isinstance(want, dict):
        assert set(got) == set(want)
        for k in want:
            assert_doc_close(got[k], want[k], tol)
    elif isinstance(want, float):
        assert got == pytest.approx(want, rel=tol, nan_ok=True)
    else:
        assert got == want


def assert_rows_match(got, want, tol=1e-12):
    """Service rows vs sweep.run rows: same shape, same labels, floats
    within rel tol (the coalesced/bucketed path reassociates sums)."""
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert set(g) == set(w)
        for k, wv in w.items():
            if isinstance(wv, float):
                assert g[k] == pytest.approx(wv, rel=tol, nan_ok=True), k
            else:
                assert g[k] == wv, k


# ---------------------------------------------------------------------------
# Coalescing: parity, exactly-once, passthrough
# ---------------------------------------------------------------------------


def _fire_concurrently(svc, docs, want=("rows", "summary")):
    """Submit every envelope from its own thread, released together so
    they land inside one coalescing window."""
    barrier = threading.Barrier(len(docs))
    responses = [None] * len(docs)

    def fire(i, d):
        barrier.wait()
        responses[i] = svc.handle({"spec": d, "want": list(want)})

    threads = [threading.Thread(target=fire, args=(i, d))
               for i, d in enumerate(docs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return responses


def test_coalesced_specs_match_individual_runs():
    # seeded property test (no hypothesis in the image): random compatible
    # spec subsets, fired concurrently, must match sweep.run() per member
    rng = random.Random(20260808)
    svc = SweepService(window_ms=250.0)
    try:
        for rnd in range(3):
            docs = []
            for i in range(4):
                scens = rng.sample(SCENARIOS,
                                   rng.randint(1, len(SCENARIOS)))
                caps = rng.choice([("3MB",), ("8MB",), CAPS])
                docs.append(doc(f"prop-{rnd}-{i}", scens,
                                designs_at(caps)))
            responses = _fire_concurrently(svc, docs)
            # exactly-once: every request got exactly one response
            assert all(r is not None for r in responses)
            for d, resp in zip(docs, responses):
                assert resp["ok"], resp.get("error")
                expected = sweep.run(SymbolicSweepSpec.from_json(d)
                                     .resolve())
                assert_rows_match(resp["rows"], expected.rows())
                assert_doc_close(resp["summary"], expected.summary())
        assert svc.coalescer.coalesced_requests > 0
        assert svc.coalescer.max_group >= 2
        assert svc.requests == svc.ok == 3 * 4
    finally:
        svc.close()


def test_identical_inflight_requests_dedup():
    d = doc("dedup-spec")
    svc = SweepService(window_ms=250.0)
    try:
        responses = _fire_concurrently(svc, [d, d, d], want=("summary",))
        assert all(r["ok"] for r in responses)
        # identical documents share one queue entry and one evaluation
        assert all(r["source"] == "coalesced" for r in responses)
        assert svc.coalescer.deduped_requests == 2
        assert svc.coalescer.batches == 1
        assert_doc_close(responses[0]["summary"], responses[1]["summary"],
                         tol=0.0)
    finally:
        svc.close()


def test_incompatible_platforms_pass_through():
    a = doc("pt-gtx", SCENARIOS[:1], platforms=("gtx-1080ti",))
    b = doc("pt-tpu", SCENARIOS[:1], platforms=("tpu-v5e",))
    svc = SweepService(window_ms=250.0)
    try:
        responses = _fire_concurrently(svc, [a, b], want=("summary",))
        assert all(r["ok"] for r in responses)
        # same batch, but different platform axes -> separate evaluations
        assert all(r["source"] == "evaluated" for r in responses)
        assert svc.coalescer.coalesced_requests == 0
    finally:
        svc.close()
    with pytest.raises(ValueError, match="platform axis"):
        spec_union([SymbolicSweepSpec.from_json(a).resolve(),
                    SymbolicSweepSpec.from_json(b).resolve()])


def test_coalescer_delivers_errors_exactly_once():
    boom = RuntimeError("engine down")

    def failing(spec):
        raise boom

    co = Coalescer(evaluate=failing, window_ms=0.0)
    try:
        spec = SymbolicSweepSpec.from_json(doc("err")).resolve()
        with pytest.raises(RuntimeError, match="engine down"):
            co.submit(spec)
    finally:
        co.close()
    with pytest.raises(RuntimeError, match="closed"):
        co.submit(spec)


# ---------------------------------------------------------------------------
# Result cache
# ---------------------------------------------------------------------------


def test_result_cache_hits_and_spec_key_stability():
    d = doc("cache-spec")
    svc = SweepService(window_ms=0.0)
    try:
        first = svc.handle({"spec": d, "want": ["summary"]})
        second = svc.handle({"spec": d, "want": ["rows"]})
        assert first["ok"] and second["ok"]
        assert first["source"] == "evaluated"
        assert second["source"] == "cache"     # want differs, spec doesn't
        assert svc.cache.hits == 1 and svc.cache.misses == 1
    finally:
        svc.close()
    sym = SymbolicSweepSpec.from_json(d)
    assert spec_key(sym) == spec_key(SymbolicSweepSpec.from_json(
        json.loads(json.dumps(d))))


def test_result_cache_bounded_eviction():
    cache = ResultCache(maxsize=2)
    for i in range(4):
        cache.put(f"k{i}", f"r{i}")
    assert len(cache) == 2
    assert cache.get("k0") is None and cache.get("k3") == "r3"
    assert (cache.hits, cache.misses) == (1, 1)


# ---------------------------------------------------------------------------
# Lifecycle: graceful shutdown
# ---------------------------------------------------------------------------


def test_close_drains_slow_inflight_request():
    release = threading.Event()

    def slow(spec):
        release.wait(5.0)
        return evaluate_spec(spec)

    svc = SweepService(window_ms=50.0, evaluate=slow)
    responses = []

    def transport():
        with svc.track():   # what every real transport does
            responses.append(svc.handle({"spec": doc("slow-spec"),
                                         "want": ["summary"]}))

    t = threading.Thread(target=transport)
    t.start()
    time.sleep(0.15)        # let the request enter the coalescing window
    release.set()
    svc.close()             # must drain: the response is delivered first
    t.join(10.0)
    assert not t.is_alive()
    assert len(responses) == 1 and responses[0]["ok"]
    # after close the service refuses evaluation but still answers
    post = svc.handle({"spec": doc("post-close"), "want": ["summary"]})
    assert not post["ok"] and "closed" in post["error"]
    svc.close()             # idempotent


# ---------------------------------------------------------------------------
# Stats
# ---------------------------------------------------------------------------


def test_stats_document_and_ops():
    svc = SweepService(window_ms=0.0)
    try:
        assert svc.handle({"op": "ping"}) == {"ok": True, "op": "ping"}
        bad = svc.handle({"op": "reboot"})
        assert not bad["ok"] and "unknown op" in bad["error"]
        d = doc("stats-spec", SCENARIOS[:1])
        svc.handle({"spec": d})
        svc.handle({"spec": d})
        svc.handle({"spec": {"schema": "bogus"}})
        stats = svc.handle({"op": "stats"})["stats"]
        # the unknown-op error above counts too: 4 requests, 2 ok
        assert stats["requests"] == {"total": 4, "ok": 2, "errors": 2}
        assert stats["result_cache"]["hits"] == 1
        assert stats["result_cache"]["misses"] == 1
        assert stats["coalesce"]["enabled"]
        assert stats["cells"]["total"] == 2 * 1 * 3  # 2 ok x 1 scen x 3 des
        assert stats["cells"]["p50"] == 3.0
        assert stats["elapsed_ms"]["p50"] > 0
        assert stats["elapsed_ms"]["p95"] >= stats["elapsed_ms"]["p50"]
        json.dumps(stats)   # the whole document must serialize
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------


def test_http_transport_roundtrip():
    svc = SweepService(window_ms=5.0)
    srv = service_mod.SweepHTTPServer(("127.0.0.1", 0), svc)
    port = srv.server_address[1]
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    url = f"127.0.0.1:{port}"
    try:
        assert client.wait_ready(url, timeout=10.0)
        resp = client.http_request(url, {"spec": doc("http-spec"),
                                         "want": ["summary"]})
        assert resp["ok"] and "summary" in resp
        bad = client.http_request(url, {"spec": {"schema": "bogus"}})
        assert not bad["ok"] and "error" in bad
        stats = client.http_stats(url)
        assert stats["ok"] and stats["stats"]["requests"]["total"] == 2
    finally:
        srv.shutdown()
        srv.server_close()
        svc.close()


@pytest.mark.skipif(service_mod.SweepUnixServer is None,
                    reason="no AF_UNIX on this platform")
def test_unix_transport_roundtrip(tmp_path):
    path = str(tmp_path / "sweep.sock")
    svc = SweepService(window_ms=5.0)
    srv = service_mod.SweepUnixServer(path, svc)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    try:
        resps = client.unix_request(path, [
            {"spec": doc("unix-spec"), "want": ["summary"]},
            {"op": "stats"},
            {"spec": {"schema": "bogus"}},
        ])
        assert resps[0]["ok"] and "summary" in resps[0]
        assert resps[1]["ok"] and resps[1]["op"] == "stats"
        assert not resps[2]["ok"]
    finally:
        srv.shutdown()
        srv.server_close()
        svc.close()


def test_serve_subprocess_sigterm_graceful():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.sweep", "serve",
         "--http", "127.0.0.1:0", "--stats-on-exit"],
        cwd=ROOT, env=env, stdin=subprocess.DEVNULL,
        stderr=subprocess.PIPE, text=True)
    try:
        url = None
        for _ in range(200):
            line = proc.stderr.readline()
            if not line:
                break
            if line.startswith("listening on http://"):
                url = line.split("http://", 1)[1].strip()
                break
        assert url, "server never reported its address"
        resp = client.http_request(
            url, {"spec": doc("sigterm-spec", SCENARIOS[:1]),
                  "want": ["summary"]}, timeout=120.0)
        assert resp["ok"]
        proc.send_signal(signal.SIGTERM)
        _, err = proc.communicate(timeout=60.0)
        assert proc.returncode == 0
        stats = json.loads(err)
        assert stats["requests"]["ok"] >= 1
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()


# ---------------------------------------------------------------------------
# LM @b<n> scenario namespace
# ---------------------------------------------------------------------------


def test_lm_batch_override_resolve_and_inverse():
    base = scenarios.resolve("lm/qwen3-14b/prefill_32k")
    s8 = scenarios.resolve("lm/qwen3-14b/prefill_32k@b8")
    assert s8.batch == 8
    assert s8.workload == "qwen3-14b/prefill_32k@b8"
    assert scenarios.name_of(s8) == "lm/qwen3-14b/prefill_32k@b8"
    assert scenarios.resolve("lm/qwen3-14b/prefill_32k@b8") is s8
    assert s8 is not base
    # both cells can share one scenario axis (distinct scenario keys)
    from repro.core.tech import GTX_1080TI
    spec = sweep.SweepSpec(name="lm-b", scenarios=(base, s8),
                           designs=sweep.design_grid(("sram", "stt"),
                                                     (3.0,)),
                           platforms=(GTX_1080TI,))
    assert len(spec.scenarios) == 2


def test_lm_batch_override_errors():
    for bad in ("lm/qwen3-14b/prefill_32k@b0",
                "lm/qwen3-14b/prefill_32k@bx",
                "lm/qwen3-14b/prefill_32k@b-1"):
        with pytest.raises(ValueError):
            scenarios.resolve(bad)
    with pytest.raises(ValueError, match="positive int"):
        scenarios.lm_traffic("qwen3-14b", "prefill_32k", batch=0)


def test_lm_batch_names_registered():
    names = scenarios.names()
    assert "lm/qwen3-14b/prefill_32k" in names
    for b in scenarios.LM_BATCHES:
        assert f"lm/qwen3-14b/prefill_32k@b{b}" in names
    # every emitted name resolves and round-trips
    for name in names:
        if name.startswith("lm/") and "@b8" in name:
            assert scenarios.name_of(scenarios.resolve(name)) == name


def test_lm_batch_cells_through_service():
    d = doc("lm-b-mix",
            scens=("lm/qwen3-14b/decode_32k", "lm/qwen3-14b/decode_32k@b32"),
            designs=designs_at(("3MB",)))
    svc = SweepService(window_ms=0.0)
    try:
        resp = svc.handle({"spec": d, "want": ["rows"]})
        assert resp["ok"], resp.get("error")
        expected = sweep.run(SymbolicSweepSpec.from_json(d).resolve())
        assert_rows_match(resp["rows"], expected.rows())
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# Backpressure: size limit (413) and admission gate (429)
# ---------------------------------------------------------------------------


def test_oversize_request_refused_with_413():
    svc = SweepService(window_ms=0.0, max_body_bytes=128)
    try:
        resp = svc.handle("x" * 256)
        assert resp["ok"] is False
        assert resp["status"] == 413
        assert "RequestTooLarge" in resp["error"]
        limits = svc.stats()["limits"]
        assert limits["rejected_too_large"] == 1
        assert limits["max_body_bytes"] == 128
        # a normally-sized request still works on the same service
        ok = svc.handle(json.dumps({"op": "ping"}))
        assert ok["ok"]
    finally:
        svc.close()


def test_overload_refused_with_429_and_cache_hits_exempt():
    release = threading.Event()

    def slow(spec):
        release.wait(timeout=60.0)
        return evaluate_spec(spec)

    svc = SweepService(window_ms=0.0, coalesce=False, evaluate=slow,
                       max_pending=1)
    warm = doc("bp-warm")
    try:
        # warm one result into the cache (no contention yet)
        release.set()
        assert svc.handle(warm)["ok"]
        release.clear()

        # occupy the single admission slot with a slow evaluation
        first = {}
        t = threading.Thread(
            target=lambda: first.update(resp=svc.handle(doc("bp-slow"))))
        t.start()
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            with svc._lock:
                if svc._pending:
                    break
            time.sleep(0.01)

        # a second evaluation is refused with 429...
        refused = svc.handle(doc("bp-refused"))
        assert refused["ok"] is False
        assert refused["status"] == 429
        assert "ServiceOverloaded" in refused["error"]
        # ...but ops and cache hits are never refused
        assert svc.handle({"op": "stats"})["ok"]
        hit = svc.handle(warm)
        assert hit["ok"] and hit["source"] == "cache"

        release.set()
        t.join(timeout=60.0)
        assert first["resp"]["ok"]
        limits = svc.stats()["limits"]
        assert limits["rejected_overloaded"] == 1
        assert limits["pending"] == 0
    finally:
        release.set()
        svc.close()


def test_http_oversize_body_refused_before_read():
    svc = SweepService(window_ms=0.0, max_body_bytes=512)
    srv = service_mod.SweepHTTPServer(("127.0.0.1", 0), svc)
    port = srv.server_address[1]
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    url = f"127.0.0.1:{port}"
    try:
        assert client.wait_ready(url, timeout=10.0)
        big = doc("http-too-big",
                  scens=tuple(SCENARIOS) * 40,
                  designs=designs_at(CAPS) * 40)
        assert len(json.dumps(big)) > 512
        resp = client.http_request(url, big)
        assert resp["ok"] is False
        assert resp["status"] == 413
        small = client.http_request(url, {"op": "ping"})
        assert small["ok"]
        stats = client.http_stats(url)["stats"]["limits"]
        assert stats["rejected_too_large"] == 1
    finally:
        srv.shutdown()
        srv.server_close()
        svc.close()

"""Anchor-identity and node-projection properties of the DTCO layer.

The node-aware refactor threads real scaling through mtj -> bitcell ->
periphery -> engine; these tests pin its two load-bearing promises:

  anchor identity   at the 16 nm anchor every projected quantity is the
                    calibrated constant bit-for-bit (s = 1.0 multiplies
                    are exact), so Table I / Table II and every golden
                    spec are unchanged by construction;
  real projection   at any other node the same quantities measurably
                    differ (no anchor constants in disguise), the
                    batched engine matches the scalar per-node path to
                    <= 1e-12, mixed-node sweeps split bit-exactly into
                    their single-node evaluations, and the deep-node
                    failure modes (STT scaling wall, sub-7 nm guard)
                    raise actionable diagnostics.
"""

import numpy as np
import pytest

from repro.core import bitcell, cachemodel, dtco, engine, mtj, tech, tuner
from repro.core.cachemodel import CacheModel, PERIPHERY_FIELDS, periphery
from repro.core.tech import TECH_16NM, TECH_7NM, scaled_node

REL = 1e-12


# ---------------------------------------------------------------------------
# Anchor identity: 16 nm is bit-for-bit the calibrated fixed point
# ---------------------------------------------------------------------------


def test_mtj_device_anchor_identity():
    assert mtj.device("stt", TECH_16NM) == mtj.STT_16NM
    assert mtj.device("sot", TECH_16NM) == mtj.SOT_16NM
    assert mtj.device("stt") == mtj.device("stt", TECH_16NM)


def test_periphery_anchor_identity():
    p = periphery(TECH_16NM)
    assert p == cachemodel._PERIPHERY_16NM
    assert periphery() == p
    # the engine's baked anchor row (the bit-identity trace) agrees too
    assert engine._PERI_16NM_ROW == tuple(
        getattr(p, f) for f in PERIPHERY_FIELDS)


# Exact Table I values produced by the pre-refactor anchor-pinned code
# (sense_lat, sense_e, wlat_set, we_set, area_norm, read_current, fr, fw).
_TABLE1_HEAD = {
    "sram": (1.2e-10, 1.3e-15, 1.2e-10, 1.3e-15, 1.0, 8.4e-05, 2, 2),
    "stt": (6.5e-10, 7.644e-14, 8.400000000000002e-09,
            1.1000586240000003e-12, 0.33999999999999997, 0.000147, 4, 4),
    "sot": (6.5e-10, 2.0020000000000003e-14, 3.1307692307692307e-10,
            8.002358861538462e-14, 0.29, 3.85e-05, 1, 3),
}


def test_bitcell_table1_anchor_bit_identical():
    for name, (slat, se, wlat, we, area, iread, fr, fw) in \
            _TABLE1_HEAD.items():
        c = bitcell.characterize(name, TECH_16NM)
        assert c.sense_latency_s == slat, name
        assert c.sense_energy_j == se, name
        assert c.write_latency_set_s == wlat, name
        assert c.write_energy_set_j == we, name
        assert c.area_norm == area, name
        assert c.read_current_a == iread, name
        assert (c.fins_read, c.fins_write) == (fr, fw), name


# Exact Table II values produced by the pre-refactor anchor-pinned code
# (read_lat_s, write_lat_s, read_e_j, write_e_j, leak_w, area_mm2).
_TABLE2_HEAD = {
    "sram": (2.9100000000000005e-09, 1.53e-09, 3.4999999999999993e-10,
             3.2000000000000003e-10, 6.442749179585304, 5.531051665241455),
    "stt": (2.9799999999999996e-09, 9.31e-09, 8.100000000000001e-10,
            3.1e-10, 0.7479188256318854, 2.340150966085292),
    "sot": (3.7100000000000002e-09, 1.38e-09, 4.900000000000001e-10,
            2.2000000000000002e-10, 0.5271832675931994, 1.950000577897137),
    "stt_isoarea": (3.3284014911279853e-09, 9.599874115459549e-09,
                    9.307037715129982e-10, 3.262352827822285e-10,
                    1.7059247403657152, 5.120080454437546),
    "sot_isoarea": (4.301232253914615e-09, 1.7801081683908728e-09,
                    6.821705102042964e-10, 2.9462996334119443e-10,
                    1.4350523897287781, 5.6401255233758),
}


def test_table2_anchor_bit_identical():
    t2 = tuner.table2()
    assert set(t2) == set(_TABLE2_HEAD)
    for name, (rlat, wlat, re, we, leak, area) in _TABLE2_HEAD.items():
        d = t2[name]
        got = (d.read_latency_s, d.write_latency_s, d.read_energy_j,
               d.write_energy_j, d.leakage_w, d.area_mm2)
        assert got == (rlat, wlat, re, we, leak, area), name


# ---------------------------------------------------------------------------
# Real projection: 7 nm measurably differs everywhere
# ---------------------------------------------------------------------------


def test_7nm_device_and_periphery_differ_from_anchor():
    for flavor in ("stt", "sot"):
        dev = mtj.device(flavor, TECH_7NM)
        anchor = mtj.device(flavor, TECH_16NM)
        for f in tech.MTJ_SCALING_EXPONENTS[flavor]:
            assert getattr(dev, f) != getattr(anchor, f), (flavor, f)
    p7, p16 = periphery(TECH_7NM), periphery(TECH_16NM)
    for f, e in tech.PERIPHERY_SCALING_EXPONENTS.items():
        if e != 0.0:
            assert getattr(p7, f) != getattr(p16, f), f


def test_7nm_designs_differ_from_anchor():
    for mem in ("sram", "stt", "sot"):
        d7 = tuner.tuned_design(mem, 3, node=TECH_7NM)
        d16 = tuner.tuned_design(mem, 3, node=TECH_16NM)
        assert d7.read_latency_s != d16.read_latency_s, mem
        assert d7.leakage_w != d16.leakage_w, mem
        assert d7.area_mm2 != d16.area_mm2, mem


# ---------------------------------------------------------------------------
# Scalar-vs-batched parity at every DTCO node
# ---------------------------------------------------------------------------


_FLOAT_FIELDS = ("read_latency_s", "write_latency_s", "read_energy_j",
                 "write_energy_j", "leakage_w", "area_mm2")


@pytest.mark.parametrize("node", dtco.NODES, ids=lambda n: n.name)
def test_engine_matches_scalar_path_at_node(node):
    for mem in ("sram", "stt", "sot"):
        scalar = tuner.tune_loop(CacheModel(mem, node=node), 3 * 2**20)
        batched = tuner.tuned_design(mem, 3, node=node)
        assert batched.org == scalar.org, (node.name, mem)
        for f in _FLOAT_FIELDS:
            assert getattr(batched, f) == pytest.approx(
                getattr(scalar, f), rel=REL), (node.name, mem, f)


def test_mixed_node_sweep_splits_bit_exactly():
    """A multi-node sweep routes the anchor row through the anchor trace
    and scaled rows through the runtime trace; each node's slice must be
    bit-identical to that node's own single-node sweep."""
    caps = (3 * 2**20,)
    mixed = engine.sweep(caps, nodes=(TECH_16NM, TECH_7NM))
    for i, node in enumerate((TECH_16NM, TECH_7NM)):
        single = engine.sweep(caps, nodes=node)
        for f in _FLOAT_FIELDS:
            a = getattr(mixed, f)[i]
            b = getattr(single, f)[0]
            assert np.array_equal(a, b), (node.name, f)


# ---------------------------------------------------------------------------
# Deep-node failure modes
# ---------------------------------------------------------------------------


def test_stt_scaling_wall_diagnostic():
    """Past the validated range the STT drive derates below the
    retention-pinned critical current; the diagnostic says so instead of
    silently returning an empty sweep."""
    node = scaled_node(2e-9, name="2nm-extrap", allow_extrapolation=True)
    with pytest.raises(ValueError,
                       match="no feasible stt bitcell.*critical current"):
        bitcell.characterize("stt", node)


def test_sub_7nm_projection_guard():
    with pytest.raises(ValueError, match="validated projection range"):
        scaled_node(5e-9)
    n = scaled_node(5e-9, name="5nm-extrap", allow_extrapolation=True)
    assert n.feature_size_m == 5e-9
    assert n.sram_cell_leak_w > TECH_7NM.sram_cell_leak_w
    assert tech.MIN_FEATURE_SIZE_M == 7e-9


def test_scaled_node_rejects_at_and_past_guard_boundary():
    assert scaled_node(tech.MIN_FEATURE_SIZE_M).feature_size_m == 7e-9
    with pytest.raises(ValueError, match="validated projection range"):
        scaled_node(tech.MIN_FEATURE_SIZE_M - 1e-12)


# ---------------------------------------------------------------------------
# Engine trace economy
# ---------------------------------------------------------------------------


def test_engine_needs_no_new_trace_per_node():
    """Node parameters are runtime tensor rows: once the anchor trace and
    the runtime trace exist for a shape, new node values must not
    recompile (the property that keeps cross-node sweeps one compile)."""
    caps = (3 * 2**20,)
    engine.sweep(caps, nodes=TECH_16NM)
    engine.sweep(caps, nodes=scaled_node(13e-9, name="warm-13nm"))
    base = engine.ppa_fn._cache_size()
    for nm in (11.0, 9.0):
        engine.sweep(caps, nodes=scaled_node(nm * 1e-9, name=f"t-{nm:g}nm"))
    assert engine.ppa_fn._cache_size() == base

"""Tests for the unified sweep pipeline (core/sweep.py).

Three families:

  parity    the sweep-backed analyses (isocap / isoarea / scaling / the
            batched lm_nvm study) pinned to the pre-refactor scalar path
            (traffic.build + traffic.energy per cell) at <= 1e-12 rel;
  property  SweepSpec axis ordering never changes row labeling — rows
            keyed by their labels are invariant under any permutation of
            the scenario / design / platform axes;
  caching   memoized folds are reused across analyses (same scenarios,
            same designs -> same objects) and the cache_clear()-style
            hooks work, guarding against silent cache-key drift.
"""

import inspect
import random

import pytest

import repro.configs as configs
from benchmarks import lm_nvm
from repro import scenarios
from repro.core import (dtco, isoarea, isocap, scaling, sweep, traffic,
                        tuner, workload_engine)
from repro.core.isocap import INFER_BATCH, TRAIN_BATCH, MEMS
from repro.core.tech import GTX_1080TI, TECH_16NM, TECH_7NM, TPU_V5E
from repro.core.workloads import alexnet, paper_workloads

REL = 1e-12
REPORT_FIELDS = ("runtime_s", "dyn_read_j", "dyn_write_j", "leak_j", "dram_j")


def _assert_row_matches_scalar(row, designs, platform=GTX_1080TI):
    """One IsoCapRow vs the pre-refactor scalar fold."""
    w = paper_workloads()[row.workload] if row.workload != "alexnet" \
        else alexnet()
    stats = traffic.build(w, row.batch, row.training)
    assert row.read_write_ratio == pytest.approx(stats.read_write_ratio,
                                                 rel=REL)
    for mem, design in designs.items():
        ref = traffic.energy(stats, design, platform)
        for f in REPORT_FIELDS:
            assert getattr(row.reports[mem], f) == pytest.approx(
                getattr(ref, f), rel=REL), (row.workload, mem, f)


# ---------------------------------------------------------------------------
# Parity: sweep-backed analyses == pre-refactor scalar outputs
# ---------------------------------------------------------------------------


def test_isocap_rows_match_scalar():
    designs = isocap.designs_at(isocap.CAPACITY_MB)
    rows = isocap.analyze()
    assert len(rows) == 2 * len(paper_workloads())
    for row in rows:
        _assert_row_matches_scalar(row, designs)


def test_isoarea_rows_match_scalar():
    designs = isoarea.designs().as_dict()
    rows = isoarea.analyze()
    assert len(rows) == 2 * len(paper_workloads())
    for row in rows:
        _assert_row_matches_scalar(row, designs)


def test_batch_sweep_rows_match_scalar():
    designs = isocap.designs_at(isocap.CAPACITY_MB)
    batches = (1, 8, 64)
    rows = isocap.batch_sweep(alexnet(), True, batches)
    assert [r.batch for r in rows] == list(batches)
    for row in rows:
        _assert_row_matches_scalar(row, designs)


def test_dram_curve_matches_scalar():
    curve = isoarea.dram_reduction_curve()
    stats = traffic.build(alexnet(), INFER_BATCH, False)
    base = stats.dram_tx(3 * 2**20)
    for cap, red in curve.items():
        ref = 100.0 * (1.0 - stats.dram_tx(cap * 2**20) / base)
        assert red == pytest.approx(ref, rel=REL, abs=1e-9)


def test_scaling_rows_match_scalar():
    caps = (1, 4)
    rows = scaling.workload_sweep(capacities_mb=caps)
    table = scaling.tuned_table(caps)
    workloads = paper_workloads()
    it = iter(rows)
    for cap in caps:
        designs = {m: table.tuned(m, int(cap * 2**20)) for m in MEMS}
        for training, batch in ((False, INFER_BATCH), (True, TRAIN_BATCH)):
            stats = {n: traffic.build(w, batch, training)
                     for n, w in workloads.items()}
            sram = {n: traffic.energy(stats[n], designs["sram"])
                    for n in workloads}
            for mem in ("stt", "sot"):
                row = next(it)
                assert (row.capacity_mb, row.mem, row.training) == \
                    (cap, mem, training)
                ex, lx, ed = [], [], []
                for n in workloads:
                    r = traffic.energy(stats[n], designs[mem])
                    ex.append(r.total_j(False) / sram[n].total_j(False))
                    lx.append(r.runtime_s / sram[n].runtime_s)
                    ed.append(r.edp(True) / sram[n].edp(True))
                assert row.energy_x == pytest.approx(
                    sum(ex) / len(ex), rel=REL)
                assert row.latency_x == pytest.approx(
                    sum(lx) / len(lx), rel=REL)
                assert row.edp_x == pytest.approx(sum(ed) / len(ed), rel=REL)
    assert next(it, None) is None


def test_lm_rows_match_scalar():
    """The batched lm_nvm fold == the pre-refactor per-cell scalar loop,
    on both platforms, including the long_500k cells the fixed guard now
    admits."""
    out = lm_nvm.run(quick=True)
    designs = {m: tuner.tuned_design(m, scenarios.LM_CAPACITY_MB)
               for m in MEMS}
    platforms = {p.name: p for p in lm_nvm.PLATFORMS}
    assert any(r["shape"] == "long_500k" for r in out["rows"])
    for row in out["rows"]:
        stats = scenarios.lm_traffic(row["arch"], row["shape"])
        reps = {m: traffic.energy(stats, d, platforms[row["platform"]])
                for m, d in designs.items()}
        assert row["rw_ratio"] == pytest.approx(stats.read_write_ratio,
                                                rel=REL)
        for mem in ("stt", "sot"):
            assert row[f"{mem}_energy_red"] == pytest.approx(
                reps["sram"].total_j(False) / reps[mem].total_j(False),
                rel=REL)
            assert row[f"{mem}_edp_red"] == pytest.approx(
                reps["sram"].edp(True) / reps[mem].edp(True), rel=REL)


def test_analyses_route_through_sweep_only():
    """The acceptance criterion, enforced at the source level: no
    per-analysis engine/fold plumbing and no scalar energy calls."""
    for mod in (isocap, isoarea, scaling, dtco):
        src = inspect.getsource(mod)
        assert "engine.design_table(" not in src, mod.__name__
        assert "workload_engine.evaluate" not in src, mod.__name__
        assert "workload_engine.stats_for(" not in src, mod.__name__
    assert "traffic.energy(" not in inspect.getsource(lm_nvm)


# ---------------------------------------------------------------------------
# The long_500k guard (the dead-branch fix)
# ---------------------------------------------------------------------------


def test_long_500k_guard_fires():
    names = [s.workload for s in scenarios.lm_scenarios()]
    subq = [a for a in configs.all_archs() if configs.get(a).sub_quadratic]
    assert subq, "no sub-quadratic arch: the guard could never fire"
    for arch in configs.all_archs():
        assert (f"{arch}/long_500k" in names) == \
            configs.get(arch).sub_quadratic, arch
        for shape in ("train_4k", "decode_32k"):
            assert f"{arch}/{shape}" in names


def test_lm_supported():
    assert scenarios.lm_supported("rwkv6-3b", "long_500k")
    assert not scenarios.lm_supported("tinyllama-1.1b", "long_500k")
    assert scenarios.lm_supported("tinyllama-1.1b", "decode_32k")


# ---------------------------------------------------------------------------
# Property: axis ordering never changes row labeling
# ---------------------------------------------------------------------------


def _row_key(r):
    return (r["platform"], r["workload"], r["batch"], r["stage"],
            r["mem"], r["capacity_mb"], r["node"], r["group"])


def _small_spec(scenarios_, designs_, platforms_, name):
    return sweep.SweepSpec(name=name, scenarios=tuple(scenarios_),
                           designs=tuple(designs_),
                           platforms=tuple(platforms_))


@pytest.fixture(scope="module")
def perm_base():
    workloads = dict(list(paper_workloads().items())[:3])
    spec = _small_spec(
        sweep.workload_scenarios(workloads, ((False, 4), (True, 8))),
        sweep.design_grid(MEMS, (1, 2), nodes=(TECH_16NM, TECH_7NM)),
        (GTX_1080TI, TPU_V5E),
        "perm-base")
    return spec, {_row_key(r): r
                  for r in sweep.run(spec).rows(include_dram=True)}


@pytest.mark.parametrize("seed", range(4))
def test_axis_permutation_keeps_row_labeling(perm_base, seed):
    """Rows keyed by their axis labels (node included) are invariant under
    any permutation of the scenario, design, and platform axes."""
    spec, base_rows = perm_base
    rng = random.Random(seed)
    scenarios_ = list(spec.scenarios)
    designs_ = list(spec.designs)
    platforms_ = list(spec.platforms)
    rng.shuffle(scenarios_)
    rng.shuffle(designs_)
    rng.shuffle(platforms_)
    permuted = _small_spec(scenarios_, designs_, platforms_,
                           f"perm-{seed}")
    rows = {_row_key(r): r
            for r in sweep.run(permuted).rows(include_dram=True)}
    assert rows.keys() == base_rows.keys()
    for k, row in rows.items():
        ref = base_rows[k]
        assert row.keys() == ref.keys()
        for field, v in row.items():
            if isinstance(v, float):
                assert v == pytest.approx(ref[field], rel=1e-15), (k, field)
            else:
                assert v == ref[field], (k, field)


# ---------------------------------------------------------------------------
# Memoization: shared folds across analyses, cache hooks
# ---------------------------------------------------------------------------


def test_run_memoized_identity():
    spec1 = _small_spec(
        sweep.workload_scenarios((alexnet(),), ((False, 4),)),
        sweep.design_grid(MEMS, (3,)),
        (GTX_1080TI,), "memo")
    spec2 = _small_spec(
        sweep.workload_scenarios((alexnet(),), ((False, 4),)),
        sweep.design_grid(MEMS, (3,)),
        (GTX_1080TI,), "memo")
    assert spec1 == spec2
    res = sweep.run(spec1)
    assert sweep.run(spec2) is res
    # the fold table is the shared memoized workload-engine evaluation
    assert workload_engine.evaluate_platforms(
        spec1.scenarios, res.designs, spec1.platforms)[0] is res.tables[0]


def test_memoization_reused_across_analyses():
    """isocap -> isoarea share scenario statistics; repeating an analysis
    adds no new fold evaluations (no silent cache-key drift)."""
    res = sweep.run(lm_nvm.spec(quick=True))
    isocap.analyze()
    ev = workload_engine.evaluate_platforms.cache_info()
    isocap.analyze()  # equal spec: memoized end to end, no new fold
    assert workload_engine.evaluate_platforms.cache_info().misses == \
        ev.misses
    # re-requesting the same fold directly hits the engine cache
    assert workload_engine.evaluate_platforms(
        res.spec.scenarios, res.designs, res.spec.platforms) \
        is res.tables
    stats_info = workload_engine.stats_for.cache_info()
    isoarea.analyze()  # same (workload, batch, training) scenarios
    assert workload_engine.stats_for.cache_info().misses == \
        stats_info.misses


def test_cache_clear_hooks():
    isocap.analyze()
    assert workload_engine.evaluate.cache_info().currsize > 0
    workload_engine.evaluate.cache_clear()
    assert workload_engine.evaluate.cache_info().currsize == 0
    assert workload_engine.evaluate_platforms.cache_info().currsize == 0
    sweep.clear_cache()  # results referencing dropped tables also go
    isocap.analyze()     # and the pipeline rebuilds cleanly
    assert workload_engine.evaluate.cache_info().currsize > 0


# ---------------------------------------------------------------------------
# SweepResult surface
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_result():
    spec = _small_spec(
        sweep.workload_scenarios((alexnet(),), ((False, 4), (True, 8))),
        sweep.design_grid(MEMS, (1, 2)),
        (GTX_1080TI, TPU_V5E), "surface")
    return sweep.run(spec)


def test_axes_and_rows_shape(small_result):
    axes = small_result.axes
    assert len(axes["platform"]) == 2
    assert len(axes["scenario"]) == 2
    assert len(axes["design"]) == 6
    rows = small_result.rows()
    assert len(rows) == 2 * 2 * 6
    assert {r["platform"] for r in rows} == {"gtx-1080ti", "tpu-v5e"}


def test_norm_baseline_is_one(small_result):
    norm = small_result.norm_to()
    for name in sweep.METRICS:
        x = norm.metric(name)
        for j, (mem, _, _) in enumerate(small_result.design_labels):
            if mem == "sram":
                assert x[:, :, j] == pytest.approx(1.0)


def test_metric_matches_tables(small_result):
    m = small_result.metric("edp", include_dram=True)
    for pi, table in enumerate(small_result.tables):
        assert (m[pi] == table.edp(True)).all()


def test_summary_structure(small_result):
    s = small_result.summary()
    assert set(s) == {"gtx-1080ti", "tpu-v5e"}
    for per_mem in s.values():
        assert set(per_mem) == {"stt", "sot"}
        for v in per_mem.values():
            assert v["edp_reduction_max"] >= v["edp_reduction_mean"] > 0


def test_to_csv(small_result, tmp_path):
    path = tmp_path / "sweep.csv"
    small_result.to_csv(str(path))
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 1 + len(small_result.rows())
    assert lines[0].startswith("platform,workload,batch,stage,mem")


def test_spec_validation():
    scen = sweep.workload_scenarios((alexnet(),), ((False, 4),))
    designs = sweep.design_grid(MEMS, (3,))
    with pytest.raises(ValueError):
        sweep.SweepSpec(scenarios=(), designs=designs)
    with pytest.raises(ValueError):
        sweep.SweepSpec(scenarios=scen + scen, designs=designs)
    with pytest.raises(ValueError):
        sweep.SweepSpec(scenarios=scen, designs=designs + designs)
    # a group without a baseline design only fails at normalization time
    no_base = sweep.SweepSpec(
        scenarios=scen, designs=sweep.design_grid(("stt", "sot"), (3,)))
    with pytest.raises(ValueError):
        sweep.run(no_base).norm_to()
    with pytest.raises(ValueError):
        sweep.run(no_base).design_index("sram")

"""Tests for the technology-node axis: scaled-node projections, the
calibration derivation rule, and the cross-node DTCO analysis.

Three families:

  scaling      tech.scaled_node reproduces the anchor at s=1, applies the
               documented exponents, and round-trips (the property the
               calibration derivation rule keys on);
  calibration  the 16 nm fixed-point fit is the single anchor, scaled
               nodes derive from it by the documented rule, and nodes
               without a rule raise instead of inheriting 16 nm constants;
  dtco         the cross-node analysis matches the scalar per-node path
               (CacheModel(mem, node=...) + traffic.energy) and shows the
               monotone SRAM-leakage / widening-gap trend it exists to
               surface.
"""

import dataclasses

import pytest

from repro.core import calibration, dtco, isoarea, sweep, tech, traffic, tuner
from repro.core.cachemodel import CacheModel
from repro.core.isocap import INFER_BATCH, TRAIN_BATCH, MEMS
from repro.core.tech import (TECH_16NM, TECH_12NM, TECH_10NM, TECH_7NM,
                             TechNode, scaled_node)
from repro.core.workloads import paper_workloads

REL = 1e-12


# ---------------------------------------------------------------------------
# scaled_node
# ---------------------------------------------------------------------------


def test_scaled_node_identity_at_anchor_size():
    n = scaled_node(16e-9)
    for f in tech.SCALING_EXPONENTS:
        assert getattr(n, f) == getattr(TECH_16NM, f), f
    assert n.feature_size_m == TECH_16NM.feature_size_m


def test_scaled_node_applies_documented_exponents():
    n = scaled_node(8e-9)
    s = 0.5
    for f, e in tech.SCALING_EXPONENTS.items():
        assert getattr(n, f) == pytest.approx(
            getattr(TECH_16NM, f) * s ** e, rel=REL), f


def test_scaled_node_directions():
    """The physics directions behind the DTCO trend: smaller nodes mean
    smaller cells, lower vdd, and a leakier 6T storage cell."""
    for smaller, larger in ((TECH_7NM, TECH_10NM), (TECH_10NM, TECH_12NM),
                            (TECH_12NM, TECH_16NM)):
        assert smaller.sram_cell_area_um2 < larger.sram_cell_area_um2
        assert smaller.vdd_v < larger.vdd_v
        assert smaller.sram_cell_leak_w > larger.sram_cell_leak_w


def test_scaled_node_round_trips():
    for node in (TECH_12NM, TECH_10NM, TECH_7NM):
        assert scaled_node(node.feature_size_m, name=node.name) == node


# ---------------------------------------------------------------------------
# calibration derivation rule
# ---------------------------------------------------------------------------


def test_calibration_anchor_is_default():
    assert calibration.get("stt") == calibration.get("stt", TECH_16NM)


def test_calibration_scaled_node_rule():
    anchor = calibration.get("sot")
    derived = calibration.get("sot", TECH_7NM)
    s = tech.scale_factor(TECH_7NM)
    assert derived.peri_area_lin == pytest.approx(
        anchor.peri_area_lin * s ** tech.PERI_AREA_EXP, rel=REL)
    assert derived.peri_area_sqrt == pytest.approx(
        anchor.peri_area_sqrt * s ** tech.PERI_AREA_EXP, rel=REL)
    assert derived.leak_lin == pytest.approx(
        anchor.leak_lin * s ** tech.PERI_LEAK_EXP, rel=REL)
    assert derived.leak_sqrt == pytest.approx(
        anchor.leak_sqrt * s ** tech.PERI_LEAK_EXP, rel=REL)
    # dimensionless multipliers transfer unchanged (the structural model
    # they multiply reads the node parameters itself)
    for k in ("k_read_lat", "k_write_lat", "k_read_e", "k_write_e"):
        assert getattr(derived, k) == getattr(anchor, k), k


def test_calibration_raises_without_derivation_rule():
    handmade = TechNode(name="mystery-8nm", feature_size_m=8e-9)
    with pytest.raises(ValueError, match="no calibration derivation rule"):
        calibration.get("sram", handmade)
    # a scaled_node with a custom name still round-trips -> still has a rule
    assert calibration.get("sram", scaled_node(8e-9, name="my-8nm"))
    # ... even one built past the extrapolation guard (the guard protects
    # construction, not recognition)
    assert calibration.get(
        "sram", scaled_node(5e-9, name="my-5nm", allow_extrapolation=True))


def test_sram_bitcell_reads_node_leakage():
    from repro.core import bitcell
    assert bitcell.sram_bitcell(TECH_16NM).cell_leakage_w == \
        TECH_16NM.sram_cell_leak_w == 2.143e-7
    assert bitcell.sram_bitcell(TECH_7NM).cell_leakage_w == \
        TECH_7NM.sram_cell_leak_w > TECH_16NM.sram_cell_leak_w


# ---------------------------------------------------------------------------
# cross-node DTCO analysis
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_dtco():
    workloads = dict(list(paper_workloads().items())[:2])
    nodes = (TECH_16NM, TECH_7NM)
    return workloads, nodes, dtco.analyze(workloads=workloads, nodes=nodes)


def test_dtco_rows_match_scalar_per_node_path(small_dtco):
    """Every DTCO cell equals the pre-batched scalar study: a per-node
    CacheModel tune + per-(workload, stage) traffic.energy fold."""
    workloads, nodes, rows = small_dtco
    stages = ((False, INFER_BATCH), (True, TRAIN_BATCH))
    it = iter(rows)
    for node in nodes:
        designs = {m: tuner.tune_loop(CacheModel(m, node=node), 3 * 2**20)
                   for m in MEMS}
        reps = {(n, m, t): traffic.energy(
                    traffic.build(w, b, t), designs[m])
                for n, w in workloads.items()
                for t, b in stages for m in MEMS}

        def mean(fn, mem, base="sram"):
            vals = [fn(reps[n, mem, t]) / fn(reps[n, base, t])
                    for n in workloads for t, _ in stages]
            return sum(vals) / len(vals)

        for mem in MEMS:
            row = next(it)
            assert (row.node, row.mem) == (node.name, mem)
            assert row.leakage_w == pytest.approx(
                designs[mem].leakage_w, rel=REL)
            assert row.area_mm2 == pytest.approx(
                designs[mem].area_mm2, rel=REL)
            assert row.energy_x == pytest.approx(
                mean(lambda r: r.total_j(False), mem), rel=REL)
            assert row.leak_x == pytest.approx(
                mean(lambda r: r.leak_j, mem), rel=REL)
            assert row.edp_x == pytest.approx(
                mean(lambda r: r.edp(True), mem), rel=REL)
            assert row.runtime_x == pytest.approx(
                mean(lambda r: r.runtime_s, mem), rel=REL)
    assert next(it, None) is None


def test_dtco_trend_sram_leakage_blowup():
    """The headline DTCO claim: SRAM leakage grows monotonically as the
    node shrinks while both MRAM flavors' leakage gap widens."""
    rows = dtco.analyze(
        workloads=dict(list(paper_workloads().items())[:1]))
    leak = {(r.node, r.mem): r for r in rows}
    names = [n.name for n in dtco.NODES]
    sram_w = [leak[n, "sram"].leakage_w for n in names]
    assert sram_w == sorted(sram_w), "SRAM leakage must grow 16nm -> 7nm"
    for mem in ("stt", "sot"):
        gap = [1.0 / leak[n, mem].leak_x for n in names]
        assert gap == sorted(gap), f"{mem} leakage gap must widen"
        edp_red = [1.0 / leak[n, mem].edp_x for n in names]
        assert edp_red[-1] > edp_red[0], f"{mem} EDP gap must widen"


def test_dtco_normalizes_per_node(small_dtco):
    """Each node's SRAM is its own baseline (never the 16 nm one)."""
    _, _, rows = small_dtco
    for r in rows:
        if r.mem == "sram":
            for f in ("energy_x", "leak_x", "edp_x", "runtime_x"):
                assert getattr(r, f) == pytest.approx(1.0, rel=1e-12)


def test_design_grid_node_groups():
    grid = sweep.design_grid(MEMS, (2, 3), nodes=(TECH_16NM, TECH_7NM))
    assert len(grid) == 2 * 2 * len(MEMS)
    groups = {p.group for p in grid}
    assert groups == {(n.name, float(c)) for n in (TECH_16NM, TECH_7NM)
                      for c in (2, 3)}
    for g in groups:
        assert sum(p.group == g and p.mem == "sram" for p in grid) == 1
    # single-node grids keep the historical bare-capacity group labels
    assert {p.group for p in sweep.design_grid(MEMS, (2, 3))} == {2.0, 3.0}


def test_lm_sweep_spec_node_axis():
    from repro import scenarios
    spec = scenarios.lm_sweep_spec(archs=("tinyllama-1.1b",),
                                   shapes=("decode_32k",),
                                   nodes=(TECH_16NM, TECH_10NM),
                                   name="lm-dtco-test")
    assert len(spec.designs) == 2 * len(sweep.MEMS)
    assert {p.node.name for p in spec.designs} == \
        {TECH_16NM.name, TECH_10NM.name}


# ---------------------------------------------------------------------------
# cross-node iso-AREA study
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_isoarea():
    workloads = dict(list(paper_workloads().items())[:2])
    nodes = (TECH_16NM, TECH_7NM)
    return workloads, nodes, dtco.isoarea_analyze(workloads=workloads,
                                                  nodes=nodes)


def test_isoarea_rows_match_scalar_per_node_path(small_isoarea):
    """Every iso-area cell equals the pre-batched scalar study: the
    per-node area budget picks the capacities, a per-node CacheModel tune
    plus traffic.energy folds produce the metrics."""
    workloads, nodes, rows = small_isoarea
    stages = ((False, INFER_BATCH), (True, TRAIN_BATCH))
    it = iter(rows)
    for node in nodes:
        corners = isoarea.corners(3.0, node=node)
        designs = {p.mem: tuner.tune_loop(
                       CacheModel(p.mem, node=node), p.capacity_bytes)
                   for p in corners}
        reps = {(n, m, t): traffic.energy(
                    traffic.build(w, b, t), designs[m])
                for n, w in workloads.items()
                for t, b in stages for m in MEMS}

        def mean(fn, mem):
            vals = [fn(reps[n, mem, t]) / fn(reps[n, "sram", t])
                    for n in workloads for t, _ in stages]
            return sum(vals) / len(vals)

        for p in corners:
            row = next(it)
            assert (row.node, row.mem) == (node.name, p.mem)
            assert row.capacity_mb == p.capacity_bytes / 2**20
            assert row.leakage_w == pytest.approx(
                designs[p.mem].leakage_w, rel=REL)
            assert row.area_mm2 == pytest.approx(
                designs[p.mem].area_mm2, rel=REL)
            assert row.energy_x == pytest.approx(
                mean(lambda r: r.total_j(False), p.mem), rel=REL)
            assert row.leak_x == pytest.approx(
                mean(lambda r: r.leak_j, p.mem), rel=REL)
            assert row.edp_x == pytest.approx(
                mean(lambda r: r.edp(True), p.mem), rel=REL)
    assert next(it, None) is None


def test_isoarea_trends_across_nodes():
    """The study's headline: the density advantage keeps buying capacity
    at every node (MRAM iso-area capacity stays well above the SRAM
    budget), the EDP gap against same-node SRAM widens monotonically as
    the node shrinks, and the SRAM baseline's leakage blows up."""
    rows = dtco.isoarea_analyze(
        workloads=dict(list(paper_workloads().items())[:1]))
    by = {(r.node, r.mem): r for r in rows}
    names = [n.name for n in dtco.NODES]
    sram_w = [by[n, "sram"].leakage_w for n in names]
    assert sram_w == sorted(sram_w) and sram_w[-1] > sram_w[0]
    for mem in ("stt", "sot"):
        caps = [by[n, mem].capacity_mb for n in names]
        assert all(c > by[names[0], "sram"].capacity_mb for c in caps), mem
        assert caps == sorted(caps, reverse=True), \
            f"{mem} iso-area capacity must not grow as the node shrinks"
        edp = [by[n, mem].edp_x for n in names]
        assert edp == sorted(edp, reverse=True), \
            f"{mem} EDP gap vs same-node SRAM must widen monotonically"
        leak = [by[n, mem].leak_x for n in names]
        assert leak == sorted(leak, reverse=True), mem


def test_isoarea_normalizes_per_node(small_isoarea):
    """Each node's SRAM corner is its own baseline."""
    _, _, rows = small_isoarea
    for r in rows:
        if r.mem == "sram":
            for f in ("energy_x", "leak_x", "edp_x", "runtime_x"):
                assert getattr(r, f) == pytest.approx(1.0, rel=1e-12)


def test_fig_dtco_isoarea_benchmark_quick():
    from benchmarks import fig_dtco_isoarea
    out = fig_dtco_isoarea.run(quick=True)
    assert "isoarea_cap" in out["derived"]
    assert len(out["rows"]) == 2 * len(MEMS)
    assert {r["node"] for r in out["rows"]} == \
        {TECH_16NM.name, TECH_7NM.name}
    b = out["bench"]
    assert b["stt_cap_mb_last"] > 3 and b["sot_cap_mb_last"] > 3
    assert b["stt_edp_reduction_last"] > 1
    assert b["sram_leak_growth"] > 1


def test_fig_dtco_benchmark_quick():
    from benchmarks import fig_dtco
    out = fig_dtco.run(quick=True)
    assert "sram_leak" in out["derived"]
    assert len(out["rows"]) == 2 * len(MEMS)
    assert {r["node"] for r in out["rows"]} == \
        {TECH_16NM.name, TECH_7NM.name}
    assert all(dataclasses.asdict(dtco.DTCORow(**{
        k: r[k] for k in r})) == r for r in out["rows"])

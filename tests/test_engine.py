"""Parity tests: the batched engine vs the scalar CacheModel reference.

The engine re-expresses every cachemodel.py equation as an array function;
these tests pin the two implementations together — per-quantity values at
sampled organizations, design-space membership, Algorithm 1 winners, the
iso-area feasibility search, and the Table II entry points.
"""

import numpy as np
import pytest

from repro.core import engine, tuner
from repro.core.cachemodel import CacheModel, CacheOrg
from repro.core.tech import TECH_16NM, TECH_7NM, TECH_10NM

MEMS = ("sram", "stt", "sot")
REL = 1e-12  # float64 agreement between the scalar and batched paths

# organizations spread across the grid (plus both feasibility edges)
SAMPLED_ORGS = [
    CacheOrg(banks=1, rows=128, cols=256, access="normal"),
    CacheOrg(banks=1, rows=128, cols=256, access="sequential"),
    CacheOrg(banks=4, rows=512, cols=512, access="fast"),
    CacheOrg(banks=8, rows=1024, cols=2048, access="normal"),
    CacheOrg(banks=32, rows=256, cols=1024, access="sequential"),
    CacheOrg(banks=16, rows=1024, cols=256, access="fast"),
]

QUANTITIES = ("read_latency_s", "write_latency_s", "read_energy_j",
              "write_energy_j", "leakage_w", "area_mm2")


@pytest.mark.parametrize("mem", MEMS)
@pytest.mark.parametrize("cap_mb", [3, 16])
def test_batched_matches_scalar_evaluate(mem, cap_mb):
    model = CacheModel(mem)
    cap = cap_mb * 2**20
    batched = model.evaluate_batch(cap, SAMPLED_ORGS)
    for org, b in zip(SAMPLED_ORGS, batched):
        s = model.evaluate_scalar(cap, org)
        for q in QUANTITIES:
            assert getattr(b, q) == pytest.approx(getattr(s, q), rel=REL), \
                f"{mem}/{cap_mb}MB/{org}: {q}"


@pytest.mark.parametrize("mem", MEMS)
def test_design_table_matches_scalar_evaluate(mem):
    cap = 3 * 2**20
    model = CacheModel(mem)
    table = engine.design_table((mem,), (cap,))
    for o in np.flatnonzero(table.valid[0])[::17]:  # every 17th valid org
        b = table.design(mem, cap, int(o))
        s = model.evaluate_scalar(cap, engine.ORGS[o])
        for q in QUANTITIES:
            assert getattr(b, q) == pytest.approx(getattr(s, q), rel=REL)


@pytest.mark.parametrize("cap_mb", [1, 3, 8, 64])
def test_valid_mask_matches_design_space(cap_mb):
    cap = cap_mb * 2**20
    scalar_orgs = set(CacheModel("stt").design_space(cap))
    mask = engine.valid_mask(np.array([cap]))[0]
    engine_orgs = {engine.ORGS[i] for i in np.flatnonzero(mask)}
    assert engine_orgs == scalar_orgs


@pytest.mark.parametrize("mem", MEMS)
@pytest.mark.parametrize("cap_mb", [2, 3, 8])
def test_tune_matches_scalar_loop(mem, cap_mb):
    """Algorithm 1 winners identical between the two execution paths."""
    model = CacheModel(mem)
    cap = cap_mb * 2**20
    batched = tuner.tune(model, cap)
    loop = tuner.tune_loop(model, cap)
    assert batched.org == loop.org
    for q in QUANTITIES:
        assert getattr(batched, q) == pytest.approx(getattr(loop, q), rel=REL)


def test_iso_area_matches_loop_search():
    """Vectorized feasibility mask == the original 64 sequential tunes."""
    from repro.core.calibration import ISO_AREA_TOLERANCE
    budget = tuner.tuned_design("sram", 3.0).area_mm2 * ISO_AREA_TOLERANCE
    for mem in ("stt", "sot"):
        model = CacheModel(mem)
        loop = max(mb for mb in range(1, 65)
                   if tuner.tune_loop(model, mb * 2**20).area_mm2 <= budget)
        assert tuner.iso_area_capacity(mem) == loop


def test_table2_winners_match_scalar_loop():
    """The Table II entry point returns the same designs as the legacy path."""
    t2 = tuner.table2()
    for col, d in t2.items():
        mem = col.split("_")[0]
        loop = tuner.tune_loop(CacheModel(mem), d.capacity_bytes)
        assert d.org == loop.org
        assert d.read_latency_s == pytest.approx(loop.read_latency_s, rel=REL)
        assert d.area_mm2 == pytest.approx(loop.area_mm2, rel=REL)


def test_design_table_memoized():
    t1 = engine.design_table(("stt",), (3 * 2**20,))
    t2 = engine.design_table(("stt",), (3 * 2**20,))
    assert t1 is t2


def test_full_cross_product_consistent_with_single_tech_tables():
    """Batch shape must not change values: [3, c, o] == stacked [1, 1, o]."""
    caps = tuple(c * 2**20 for c in (1, 4, 32))
    full = engine.design_table(MEMS, caps)
    for mem in MEMS:
        for cap in caps:
            single = engine.design_table((mem,), (cap,))
            a = full.tuned(mem, cap)
            b = single.tuned(mem, cap)
            assert a.org == b.org
            for q in QUANTITIES:
                # XLA may vectorize pow differently per batch shape: allow
                # last-ulp drift, nothing more
                assert getattr(a, q) == pytest.approx(getattr(b, q), rel=REL)


def test_empty_design_space_raises():
    table = engine.design_table(("stt",), (3 * 2**20,))
    with pytest.raises(ValueError):
        table.tuned("stt", 999)  # unknown capacity
    tiny = engine.sweep((512,), mems=("stt",))
    assert not tiny.valid.any()
    with pytest.raises(ValueError):
        tiny.tuned("stt", 512)


# ---------------------------------------------------------------------------
# The batched TechNode axis
# ---------------------------------------------------------------------------


def test_design_table_memo_keyed_by_node():
    """Regression: the memo key includes the node(s).  Before the fix a
    non-default node silently shared the 16 nm entry."""
    cap = 3 * 2**20
    t16 = engine.design_table(("sram",), (cap,))
    t7 = engine.design_table(("sram",), (cap,), nodes=(TECH_7NM,))
    assert t16 is not t7
    # different nodes must return genuinely different tables
    assert float(t7.leakage_w[0, 0, 0]) != \
        pytest.approx(float(t16.leakage_w[0, 0, 0]), rel=1e-3)
    assert float(t7.area_mm2[0, 0, 0]) < float(t16.area_mm2[0, 0, 0])
    # a bare TechNode and a 1-tuple normalize to the same memo entry
    assert engine.design_table(("sram",), (cap,), nodes=TECH_7NM) is t7
    assert engine.design_table(("sram",), (cap,), nodes=(TECH_16NM,)) is t16


@pytest.mark.parametrize("mem", MEMS)
def test_node_axis_matches_scalar(mem):
    """One table spanning 2 nodes x 3 mems x a capacity grid, pinned per
    node to the scalar CacheModel(mem, node=...) path (<= 1e-12)."""
    caps = tuple(c * 2**20 for c in (1, 3, 8))
    nodes = (TECH_16NM, TECH_7NM)
    table = engine.design_table(MEMS, caps, nodes=nodes)
    for node in nodes:
        model = CacheModel(mem, node=node)
        for ci, cap in enumerate(caps):
            for o in np.flatnonzero(table.valid[ci])[::29]:
                b = table.design(mem, cap, int(o), node=node)
                s = model.evaluate_scalar(cap, engine.ORGS[o])
                for q in QUANTITIES:
                    assert getattr(b, q) == pytest.approx(
                        getattr(s, q), rel=REL), (node.name, mem, cap, q)


@pytest.mark.parametrize("node", [TECH_7NM, TECH_10NM],
                         ids=lambda n: n.name)
def test_node_axis_tuned_matches_scalar_loop(node):
    """Algorithm 1 winners at a non-default node match the scalar loop."""
    cap = 3 * 2**20
    table = engine.design_table(MEMS, (cap,), nodes=(TECH_16NM, node))
    for mem in MEMS:
        batched = table.tuned(mem, cap, node=node)
        loop = tuner.tune_loop(CacheModel(mem, node=node), cap)
        assert batched.org == loop.org
        for q in QUANTITIES:
            assert getattr(batched, q) == pytest.approx(
                getattr(loop, q), rel=REL), (node.name, mem, q)


def test_multi_node_consistent_with_single_node_tables():
    """The node batch shape must not change values: [2, m, c, o] equals
    the stacked single-node tables."""
    cap = 3 * 2**20
    multi = engine.design_table(MEMS, (cap,), nodes=(TECH_16NM, TECH_7NM))
    for node in (TECH_16NM, TECH_7NM):
        single = engine.design_table(MEMS, (cap,), nodes=(node,))
        for mem in MEMS:
            a = multi.tuned(mem, cap, node=node)
            b = single.tuned(mem, cap)
            assert a.org == b.org
            for q in QUANTITIES:
                assert getattr(a, q) == pytest.approx(getattr(b, q), rel=REL)


def test_multi_node_table_requires_node():
    cap = 3 * 2**20
    table = engine.design_table(("stt",), (cap,),
                                nodes=(TECH_16NM, TECH_7NM))
    with pytest.raises(ValueError, match="pass node"):
        table.tuned("stt", cap)
    with pytest.raises(ValueError, match="not in table"):
        table.tuned("stt", cap, node=TECH_10NM)
    # single-node tables keep the implicit-node convenience
    single = engine.design_table(("stt",), (cap,))
    assert single.tuned("stt", cap).org is not None

"""Property tests (hypothesis) for the cache simulator + traffic model."""

import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.cachesim import (SetAssocCache, misses_at_capacity,  # noqa: E402
                                 stack_distance_profile, trace_from_streams)
from repro.core.traffic import INF, AccessStream, TrafficStats  # noqa: E402

traces = st.lists(st.integers(0, 40), min_size=1, max_size=300)


@given(traces)
@settings(max_examples=50, deadline=None)
def test_stack_distance_matches_fully_assoc_lru(trace):
    """Mattson inclusion: profile misses == exact fully-assoc LRU misses."""
    dist = stack_distance_profile(trace)
    for cap in (1, 2, 4, 8, 64):
        sim = SetAssocCache(cap, assoc=cap)  # fully associative
        for b in trace:
            sim.access(b)
        assert sim.stats.misses == misses_at_capacity(dist, cap)


@given(traces)
@settings(max_examples=30, deadline=None)
def test_miss_curve_monotone_in_capacity(trace):
    dist = stack_distance_profile(trace)
    misses = [misses_at_capacity(dist, c) for c in (1, 2, 4, 8, 16, 64)]
    assert all(a >= b for a, b in zip(misses, misses[1:]))
    assert misses[0] <= len(trace)
    # cold misses are a floor
    assert misses[-1] >= len(set(trace))


@given(traces, st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_set_assoc_writebacks_bounded(trace, assoc):
    sim = SetAssocCache(8, assoc=assoc)
    n_writes = 0
    for i, b in enumerate(trace):
        is_write = (i % 3 == 0)
        n_writes += is_write
        sim.access(b, is_write)
    assert sim.stats.writebacks <= n_writes
    assert sim.stats.misses <= sim.stats.accesses


streams = st.lists(
    st.tuples(st.floats(1.0, 1e9), st.booleans(),
              st.one_of(st.just(INF), st.floats(1.0, 1e8))),
    min_size=1, max_size=20)


@given(streams)
@settings(max_examples=50, deadline=None)
def test_dram_traffic_monotone_in_capacity(spec):
    stats = TrafficStats(
        "prop", 1, False,
        tuple(AccessStream(f"s{i}", b, w, rd)
              for i, (b, w, rd) in enumerate(spec)), 1e9)
    caps = [2**20 * c for c in (1, 2, 4, 8, 32, 128)]
    tx = [stats.dram_tx(c) for c in caps]
    assert all(a >= b - 1e-6 for a, b in zip(tx, tx[1:]))
    assert tx[-1] >= 0.0
    # DRAM traffic never exceeds total L2 traffic
    assert tx[0] <= stats.l2_read_tx + stats.l2_write_tx + 1e-6


lowerable = st.lists(
    st.tuples(st.floats(4096.0, 4096.0 * 48), st.booleans(),
              st.one_of(st.just(INF), st.floats(4096.0, 4096.0 * 128))),
    min_size=1, max_size=8)


@given(lowerable)
@settings(max_examples=30, deadline=None)
def test_lowered_trace_miss_curve_monotone(spec):
    """misses_at_capacity is non-increasing in capacity on lowered traces,
    and finite reuse distances produce non-cold hits at large capacity."""
    strs = [AccessStream(f"s{i}", b, w, rd)
            for i, (b, w, rd) in enumerate(spec)]
    trace = trace_from_streams(strs, block_bytes=4096)
    dist = stack_distance_profile([b for b, _ in trace])
    misses = [misses_at_capacity(dist, c)
              for c in (1, 2, 4, 8, 16, 64, 1 << 20)]
    assert all(a >= b for a, b in zip(misses, misses[1:]))
    # at huge capacity only cold misses remain; re-touches all hit
    unique = len({b for b, _ in trace})
    assert misses[-1] == unique
    if any(rd != INF for _, _, rd in spec):
        assert misses[-1] < len(trace)


@given(streams)
@settings(max_examples=50, deadline=None)
def test_streaming_accesses_always_miss(spec):
    stats = TrafficStats(
        "prop", 1, False,
        tuple(AccessStream(f"s{i}", b, w, INF)
              for i, (b, w, _) in enumerate(spec)), 1e9)
    total = stats.l2_read_tx + stats.l2_write_tx
    assert stats.dram_tx(1 << 40) == abs(total) or \
        abs(stats.dram_tx(1 << 40) - total) < 1e-6

"""Deterministic regression tests for the trace-driven cache simulator.

Companion to the hypothesis suite in test_cachesim.py (which is skipped
when hypothesis is unavailable): pins the trace lowering's reuse
semantics — the fix that makes the cross-validation against the analytic
dram_tx model non-vacuous — and the simulator's degenerate-geometry
validation, with no optional dependencies.
"""

import pytest

from repro.core import traffic
from repro.core.cachesim import (SetAssocCache, misses_at_capacity,
                                 stack_distance_profile, trace_from_streams)
from repro.core.traffic import INF, AccessStream
from repro.core.workloads import alexnet

BLOCK = 4096


def test_finite_reuse_distance_produces_hits():
    """A finite-RD stream is re-touched and hits at sufficient capacity —
    every access was a cold miss before the lowering fix."""
    streams = [AccessStream("reused", 16 * BLOCK, False, 8 * BLOCK),
               AccessStream("streaming", 16 * BLOCK, True, INF)]
    trace = trace_from_streams(streams, block_bytes=BLOCK)
    unique = len({b for b, _ in trace})
    assert len(trace) == unique + 16  # one re-touch per reused block
    dist = stack_distance_profile([b for b, _ in trace])
    # big cache: only cold misses remain -> the re-touches are hits
    assert misses_at_capacity(dist, 1 << 20) == unique < len(trace)
    # tiny cache: the re-touches miss again, like the analytic miss curve
    assert misses_at_capacity(dist, 2) == len(trace)


def test_reuse_hit_threshold_tracks_reuse_distance():
    """Hits appear once capacity covers ~RD bytes of intervening traffic."""
    rd_blocks = 8
    streams = [AccessStream("s", 32 * BLOCK, False, rd_blocks * BLOCK)]
    trace = trace_from_streams(streams, block_bytes=BLOCK)
    dist = stack_distance_profile([b for b, _ in trace])
    small = misses_at_capacity(dist, rd_blocks // 4)
    large = misses_at_capacity(dist, 4 * rd_blocks)
    assert large < small  # capacity past the reuse window converts misses


def test_streaming_trace_stays_cold():
    """RD=inf streams are touched once: lowering adds no re-touches."""
    streams = [AccessStream("a", 8 * BLOCK, False, INF),
               AccessStream("b", 8 * BLOCK, True, INF)]
    trace = trace_from_streams(streams, block_bytes=BLOCK)
    assert len(trace) == 16 == len({b for b, _ in trace})


def test_trace_cross_validates_analytic_model_direction():
    """Trace-sim misses and analytic dram_tx agree on capacity ordering
    for a real (scaled-down) workload — the non-vacuous cross-check."""
    stats = traffic.build(alexnet(), batch=1, training=False)
    trace = trace_from_streams(stats.streams, block_bytes=BLOCK,
                               max_blocks_per_stream=64)
    dist = stack_distance_profile([b for b, _ in trace])
    caps_blocks = (64, 256, 1024, 4096)
    sim = [misses_at_capacity(dist, c) for c in caps_blocks]
    analytic = [stats.dram_tx(c * BLOCK) for c in caps_blocks]
    assert all(a >= b for a, b in zip(sim, sim[1:]))
    assert all(a >= b for a, b in zip(analytic, analytic[1:]))
    # both models must see actual reuse: larger caches filter traffic
    assert sim[-1] < sim[0]
    assert analytic[-1] < analytic[0]


def test_misses_monotone_non_increasing_in_capacity():
    streams = [AccessStream(f"s{i}", (4 + 8 * i) * BLOCK, i % 2 == 0,
                            INF if i % 3 == 0 else (2 << i) * BLOCK)
               for i in range(6)]
    trace = trace_from_streams(streams, block_bytes=BLOCK)
    dist = stack_distance_profile([b for b, _ in trace])
    misses = [misses_at_capacity(dist, c)
              for c in (1, 2, 4, 8, 16, 64, 256, 1 << 16)]
    assert all(a >= b for a, b in zip(misses, misses[1:]))
    assert misses[-1] == len({b for b, _ in trace})


def test_stack_distance_matches_exact_sim_on_retouch_trace():
    """Mattson profile still agrees with the exact LRU sim on traces that
    now contain re-touches."""
    streams = [AccessStream("r", 12 * BLOCK, False, 4 * BLOCK),
               AccessStream("w", 6 * BLOCK, True, 2 * BLOCK)]
    trace = trace_from_streams(streams, block_bytes=BLOCK)
    dist = stack_distance_profile([b for b, _ in trace])
    for cap in (2, 4, 8, 32):
        sim = SetAssocCache(cap, assoc=cap)  # fully associative
        for b, w in trace:
            sim.access(b, w)
        assert sim.stats.misses == misses_at_capacity(dist, cap)


def test_degenerate_geometry_rejected():
    for capacity, assoc in ((0, 16), (-3, 16), (4, 0), (4, -1)):
        with pytest.raises(ValueError):
            SetAssocCache(capacity, assoc)


def test_capacity_below_assoc_keeps_full_capacity():
    """capacity_blocks < assoc degrades to fully-associative at the full
    capacity instead of silently dropping blocks (or crashing)."""
    sim = SetAssocCache(5, assoc=16)
    assert sim.n_sets == 1 and sim.assoc == 5
    for b in range(5):
        sim.access(b)
    for b in range(5):
        assert sim.access(b)  # all five blocks resident -> hits
    assert sim.stats.misses == 5


def test_no_zero_byte_streams_in_build_output():
    """_backward_streams no longer emits zero-byte bw.w+ streams for
    layers with a single weight tile (e.g. every fc layer)."""
    stats = traffic.build(alexnet(), batch=4, training=True)
    assert all(s.bytes_total > 0 for s in stats.streams)
    labels = {s.label for s in stats.streams}
    assert "fc6.bw.w+" not in labels  # fc: amp_w == 1, no re-read stream
    assert "fc6.bw.w" in labels

"""Tests for the DSE reductions (core/dse.py): Pareto fronts pinned
against a brute-force scalar check, and capacity-plateau detection."""

import random

import numpy as np
import pytest

from repro.core import dse, sweep
from repro.core.isocap import MEMS
from repro.core.workloads import paper_workloads

CAPS_MB = (1, 2, 4, 8)   # the multi-capacity axis the fronts reduce


@pytest.fixture(scope="module")
def multi_cap_result():
    spec = sweep.SweepSpec(
        name="dse-test",
        scenarios=sweep.workload_scenarios(
            dict(list(paper_workloads().items())[:2]),
            ((False, 4), (True, 64))),
        designs=sweep.design_grid(MEMS, CAPS_MB),
        platforms=(sweep.GTX_1080TI,))
    return sweep.run(spec)


# ---------------------------------------------------------------------------
# pareto_mask: brute-force scalar reference
# ---------------------------------------------------------------------------


def _dominates(a, b) -> bool:
    return all(x <= y for x, y in zip(a, b)) and any(
        x < y for x, y in zip(a, b))


def _brute_force_front(points) -> set[int]:
    return {j for j, p in enumerate(points)
            if not any(_dominates(q, p)
                       for i, q in enumerate(points) if i != j)}


@pytest.mark.parametrize("seed", range(8))
def test_pareto_mask_matches_brute_force(seed):
    rng = random.Random(seed)
    n, k = rng.randint(2, 24), rng.randint(1, 4)
    pts = [[rng.choice((0.25, 0.5, 1.0, 2.0)) for _ in range(k)]
           for _ in range(n)]                    # ties included on purpose
    mask = dse.pareto_mask(np.array(pts))
    assert set(np.flatnonzero(mask)) == _brute_force_front(pts)


def test_pareto_mask_duplicates_survive_together():
    # two identical points: neither strictly dominates the other
    mask = dse.pareto_mask(np.array([[1.0, 1.0], [1.0, 1.0], [2.0, 2.0]]))
    assert mask.tolist() == [True, True, False]


# ---------------------------------------------------------------------------
# pareto_front on a real multi-capacity sweep
# ---------------------------------------------------------------------------


def test_pareto_front_matches_brute_force(multi_cap_result):
    """Acceptance pin: the sweep-level front equals the brute-force scalar
    check over every (platform, scenario) cell of a multi-capacity
    sweep."""
    res = multi_cap_result
    objectives = ("energy", "runtime", "area")
    front_rows = res.pareto_front(objectives)
    got = {}
    for r in front_rows:
        got.setdefault((r["platform"], r["workload"], r["stage"]),
                       set()).add(r["design_index"])
    energy = res.metric("energy")
    runtime = res.metric("runtime")
    area = [d.area_mm2 for d in res.designs]
    for pi, platform in enumerate(res.platform_labels):
        for si, (workload, _, training) in enumerate(res.scenario_labels):
            pts = [(float(energy[pi, si, j]), float(runtime[pi, si, j]),
                    area[j]) for j in range(len(res.designs))]
            ref = _brute_force_front(pts)
            key = (platform, workload, "train" if training else "infer")
            assert got[key] == ref, key


def test_pareto_front_rows_are_consistent(multi_cap_result):
    rows = multi_cap_result.pareto_front()
    assert rows, "front must be non-empty"
    for r in rows:
        j = r["design_index"]
        point = multi_cap_result.spec.designs[j]
        assert (r["mem"], r["capacity_mb"]) == (point.mem, point.capacity_mb)
        assert r["area"] == multi_cap_result.designs[j].area_mm2
        assert r["front_size"] >= 1
    # single-objective front = the argmin designs only
    per_cell = {}
    for r in multi_cap_result.pareto_front(("edp",), include_dram=True):
        per_cell.setdefault((r["platform"], r["workload"], r["stage"]),
                            []).append(r)
    edp = multi_cap_result.metric("edp", include_dram=True)
    for rows_ in per_cell.values():
        assert len(rows_) == 1 or len(
            {r["edp"] for r in rows_}) == 1      # ties only
    assert min(r["edp"] for r in rows_) == pytest.approx(
        float(edp.min(axis=2)[-1, -1]), rel=0, abs=0)


# ---------------------------------------------------------------------------
# capacity plateaus
# ---------------------------------------------------------------------------


def test_capacity_plateaus_brute_force(multi_cap_result):
    res = multi_cap_result
    rel_tol = 0.05
    plateaus = res.capacity_plateaus("edp", include_dram=True,
                                     rel_tol=rel_tol)
    # every (platform, scenario, mem) cell of the 4-capacity grid reports
    assert len(plateaus) == (len(res.platform_labels)
                             * len(res.scenario_labels) * len(MEMS))
    edp = res.metric("edp", include_dram=True)
    by_mem = {m: [res.design_index(m, float(c)) for c in CAPS_MB]
              for m in MEMS}
    for row in plateaus:
        pi = res.platform_labels.index(row["platform"])
        si = [i for i, (w, _, t) in enumerate(res.scenario_labels)
              if w == row["workload"]
              and ("train" if t else "infer") == row["stage"]][0]
        v = [float(edp[pi, si, j]) for j in by_mem[row["mem"]]]
        best = min(v)
        ref_plateau = next(c for c, val in zip(CAPS_MB, v)
                           if val <= best * (1 + rel_tol))
        assert row["plateau_capacity_mb"] == ref_plateau
        assert row["best_capacity_mb"] == CAPS_MB[v.index(best)]
        assert row["plateau_penalty"] <= rel_tol + 1e-12
        assert row["plateau_capacity_mb"] <= row["best_capacity_mb"]


def test_plateau_skips_single_capacity_axes():
    from repro.core import isocap
    res = sweep.run(isocap.spec())
    assert res.capacity_plateaus() == []


def test_objective_tensor_area_broadcast(multi_cap_result):
    t = dse.objective_tensor(multi_cap_result, "area")
    assert t.shape == multi_cap_result.metric("energy").shape
    assert (t[0, 0] == t[-1, -1]).all()

"""Per-arch smoke tests (reduced configs) + decode-vs-forward consistency."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import lm as lm_mod

ARCHS = configs.all_archs()


@functools.lru_cache(maxsize=None)
def _built(arch):
    """Shared (cfg, model, params) per arch — eager init of the bigger
    reduced configs is seconds each, and the three per-arch tests only
    read the (immutable) params."""
    cfg = configs.get(arch, reduced=True)
    model = lm_mod.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _batch_for(cfg, B, S, key):
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :S], "labels": tokens[:, 1:]}
    kw = {}
    if cfg.encdec is not None:
        kw["frames"] = jax.random.normal(
            jax.random.fold_in(key, 7),
            (B, cfg.encdec.n_frames, cfg.d_model)).astype(jnp.bfloat16)
        batch.update(kw)
    return tokens, batch, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg, model, params = _built(arch)
    B, S = 2, 32
    tokens, batch, kw = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux, _ = model.forward(params, batch["tokens"], **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg, model, params = _built(arch)
    _, batch, _ = _batch_for(cfg, 2, 32, jax.random.PRNGKey(1))
    # MoE: eager per-expert dispatch dwarfs the compile, so jit; for the
    # small dense/ssm configs the compile is the slower path — stay eager
    grad_fn = jax.value_and_grad(model.loss)
    if cfg.moe is not None:
        grad_fn = jax.jit(grad_fn)
    loss, grads = grad_fn(params, batch)
    assert np.isfinite(float(loss))
    gleaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32)))
               for g in gleaves)
    assert sum(float(jnp.sum(jnp.abs(g))) for g in gleaves) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg, model, params = _built(arch)
    B, S = 2, 16
    tokens, _, kw = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    full, _, _ = model.forward(params, tokens, **kw)
    cache = model.init_cache(B, 32)
    _, cache = model.prefill(params, tokens[:, :S], cache, **kw)
    step, _ = model.decode_step(params, tokens[:, S:S + 1], cache, S, **kw)
    a = np.asarray(full[:, -1, :], np.float32)
    b = np.asarray(step[:, 0, :], np.float32)
    rel = np.max(np.abs(a - b)) / max(1e-6, np.max(np.abs(a)))
    assert rel < 0.03, f"{arch}: decode diverges from forward ({rel:.4f})"


@pytest.mark.parametrize("arch", ["hymba-1.5b", "rwkv6-3b"])
def test_subquadratic_state_is_constant_size(arch):
    """long_500k eligibility: decode state must not scale with context."""
    cfg, model, _ = _built(arch)
    small = model.init_cache(1, 64)
    big = model.init_cache(1, 4096)
    small_b = sum(x.size * x.dtype.itemsize
                  for x in jax.tree.leaves(small))
    big_b = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(big))
    if arch == "rwkv6-3b":
        assert small_b == big_b      # O(1) state
    else:
        # hymba: only the global-attn layers scale with context; the SWA
        # ring buffers and SSM states are context-independent
        glb_frac = len(cfg.ssm.global_attn_layers) / cfg.n_layers
        assert big_b < small_b * (4096 / 64) * (glb_frac + 0.15)


def test_multi_step_decode_consistency():
    """Greedy decode token-by-token equals teacher-forced forward."""
    cfg, model, params = _built("tinyllama-1.1b")
    B, S = 1, 24
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full, _, _ = model.forward(params, tokens)
    cache = model.init_cache(B, S)
    _, cache = model.prefill(params, tokens[:, :8], cache)
    outs = []
    step_fn = jax.jit(model.decode_step)  # compiled once, 16 fast steps
    for i in range(8, S):
        logits, cache = step_fn(params, tokens[:, i:i + 1], cache, i)
        outs.append(np.asarray(logits[:, 0], np.float32))
    ref = np.asarray(full[:, 8:, :], np.float32)
    got = np.stack(outs, axis=1)
    rel = np.max(np.abs(ref - got)) / np.max(np.abs(ref))
    assert rel < 0.03


def test_remat_matches_no_remat():
    cfg = configs.get("qwen3-14b", reduced=True)
    m_full = lm_mod.LM(cfg, remat="full")
    m_none = lm_mod.LM(cfg, remat="none")
    params = m_full.init(jax.random.PRNGKey(0))
    _, batch, _ = _batch_for(cfg, 2, 32, jax.random.PRNGKey(1))
    l1, g1 = jax.value_and_grad(m_full.loss)(params, batch)
    l2, g2 = jax.value_and_grad(m_none.loss)(params, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=1e-4)  # bf16 recompute

"""Tests for the symbolic SweepSpec v2 layer (core/sweep.py, scenarios
registry, sweep CLI).

Families:

  naming     design-name / scenario-name parsing and their inverses,
             registry resolution errors, node/platform registries;
  round-trip from_json(to_json(spec)) == spec, and the resolved spec's
             run() returns the *same memoized* SweepResult object as the
             equivalent Python-constructed spec (randomized axis subsets
             + the golden files);
  golden     specs/{isocap,dtco,lm_nvm,mixed_cnn_lm}.json resolve to the
             exact Python specs of the analyses they mirror;
  cli        `python -m repro.sweep run` reproduces the Python pipeline's
             rows bit-for-bit (full-precision CSV), and serve mode
             answers JSONL requests and survives bad ones;
  rows       group labels serialize as stable strings and survive a CSV
             round-trip (no repr'd tuples);
  query      filter()/select() on labeled axes.
"""

import csv
import io
import json
import os
import random

import pytest

from benchmarks import lm_nvm
from repro import scenarios, sweep_cli
from repro.core import dtco, isocap, sweep, tech, workload_engine, workloads
from repro.core.sweep import DesignCorners, DesignGrid, SymbolicSweepSpec
from repro.core.tech import TECH_16NM, TECH_7NM, TECH_12NM

SPEC_DIR = os.path.join(os.path.dirname(__file__), "..", "specs")


def spec_path(name: str) -> str:
    return os.path.join(SPEC_DIR, name)


# ---------------------------------------------------------------------------
# Naming: design names, scenario names, registries
# ---------------------------------------------------------------------------


def test_parse_design_roundtrip():
    for name, parsed in (
            ("sram@3MB", ("sram", 3.0, TECH_16NM)),
            ("stt@48MB", ("stt", 48.0, TECH_16NM)),
            ("sot@10MB@7nm", ("sot", 10.0, TECH_7NM)),
            ("stt@1.5MB@12nm-scaled", ("stt", 1.5, TECH_12NM))):
        assert sweep.parse_design(name) == parsed
    # name -> point -> name round-trips (anchor node omitted)
    point = sweep.DesignPoint("sot", int(10 * 2**20), node=TECH_7NM)
    assert sweep.design_name(point) == "sot@10MB@7nm-scaled"
    assert sweep.parse_design(sweep.design_name(point))[2] == TECH_7NM


def test_parse_design_errors():
    for bad in ("sram", "sram@3", "sram@3MB@7nm@extra", "@3MB", "sram@MB"):
        with pytest.raises(ValueError):
            sweep.parse_design(bad)


def test_node_registry():
    assert tech.node("16nm-finfet") is TECH_16NM
    assert tech.node("16nm") is TECH_16NM
    assert tech.node("7nm-scaled") is TECH_7NM
    assert tech.node("7nm") == TECH_7NM
    # arbitrary in-range projections resolve through scaled_node
    assert tech.node("8nm").feature_size_m == pytest.approx(8e-9)
    # ... but shorthands below the validated projection range error out
    # (symbolic specs cannot carry allow_extrapolation)
    with pytest.raises(ValueError, match="below the validated"):
        tech.node("5nm")
    with pytest.raises(ValueError):
        tech.node("16lpp")


def test_platform_registry():
    assert tech.platform("gtx-1080ti") is tech.GTX_1080TI
    assert tech.platform("tpu-v5e") is tech.TPU_V5E
    with pytest.raises(ValueError):
        tech.platform("h100")


def test_workload_registry():
    assert workloads.get("alexnet").name == "alexnet"
    with pytest.raises(ValueError):
        workloads.get("resnet50")


def test_scenario_resolve_and_inverse():
    s = scenarios.resolve("cnn/alexnet/train@b64")
    assert (s.workload, s.batch, s.training) == ("alexnet", 64, True)
    assert scenarios.name_of(s) == "cnn/alexnet/train@b64"
    # memoized: equal names share one TrafficStats object
    assert scenarios.resolve("cnn/alexnet/train@b64") is s
    lm = scenarios.resolve("lm/qwen3-14b/prefill_32k")
    assert lm.workload == "qwen3-14b/prefill_32k"
    assert scenarios.name_of(lm) == "lm/qwen3-14b/prefill_32k"
    assert scenarios.resolve("lm/qwen3-14b/prefill_32k") is lm


def test_scenario_resolve_errors():
    for bad in ("gpu/alexnet/infer@b4",          # unknown namespace
                "cnn/resnet50/infer@b4",         # unknown workload
                "cnn/alexnet/serve@b4",          # unknown stage
                "cnn/alexnet/infer",             # missing batch
                "cnn/alexnet/infer@bx",          # bad batch
                "lm/qwen3-14b/decode_64k",       # unknown shape
                "lm/gpt5/decode_32k",            # unknown arch
                "lm/qwen3-14b/long_500k"):       # quadratic arch, 500k
        with pytest.raises(ValueError):
            scenarios.resolve(bad)


def test_registry_names_resolve():
    names = scenarios.names()
    assert "cnn/alexnet/infer@b4" in names
    assert "lm/qwen3-14b/prefill_32k" in names   # the widened shape
    assert "lm/rwkv6-3b/long_500k" in names
    assert "lm/qwen3-14b/long_500k" not in names
    for name in names:
        scenarios.resolve(name)


def test_prefill_32k_in_lm_shapes():
    assert "prefill_32k" in scenarios.LM_SHAPES
    cells = [s.workload for s in scenarios.lm_scenarios()]
    import repro.configs as configs
    for arch in configs.all_archs():
        assert f"{arch}/prefill_32k" in cells


# ---------------------------------------------------------------------------
# design_corners nodes= (parity with design_grid)
# ---------------------------------------------------------------------------


def test_design_corners_single_node_unchanged():
    pts = sweep.design_corners((("sram", 3), ("stt", 7), ("sot", 10)))
    assert all(p.node == TECH_16NM and p.group == 0 for p in pts)
    # identical to the historical (pre-nodes) output
    assert pts == tuple(sweep.DesignPoint(m, int(c * 2**20), group=0)
                        for m, c in (("sram", 3), ("stt", 7), ("sot", 10)))


def test_design_corners_multi_node_groups():
    pts = sweep.design_corners((("sram", 3), ("stt", 7)),
                               nodes=(TECH_16NM, TECH_7NM))
    assert [p.node for p in pts] == [TECH_16NM, TECH_16NM,
                                     TECH_7NM, TECH_7NM]
    # per-node groups: each node normalizes against its own baseline
    assert [p.group for p in pts] == [
        ("16nm-finfet", 0), ("16nm-finfet", 0),
        ("7nm-scaled", 0), ("7nm-scaled", 0)]


def test_isoarea_corners_per_node():
    """The per-node iso-area study the nodes= parameter unblocks: the
    area budget (and so the MRAM capacities) re-derives from the target
    node's designs."""
    from repro.core import isoarea
    pts = isoarea.corners(node=TECH_7NM)
    assert all(p.node == TECH_7NM and p.group == 0 for p in pts)
    caps = {p.mem: p.capacity_mb for p in pts}
    assert caps["sram"] == 3.0
    assert caps["stt"] >= 3.0 and caps["sot"] >= caps["stt"]


def test_corners_registry_form():
    sym = SymbolicSweepSpec(
        scenarios=("cnn/alexnet/infer@b4",),
        designs=sweep.DesignCorners(points=("sram@3MB", "stt@7MB",
                                            "sot@10MB"),
                                    nodes=("16nm", "7nm")))
    pts = sym.design_points()
    assert pts == sweep.design_corners(
        (("sram", 3), ("stt", 7), ("sot", 10)),
        nodes=(TECH_16NM, TECH_7NM))
    # corner names must not smuggle nodes past a non-empty 'nodes' field
    with pytest.raises(ValueError, match="must not name a node"):
        SymbolicSweepSpec(
            scenarios=("cnn/alexnet/infer@b4",),
            designs=sweep.DesignCorners(points=("stt@7MB@7nm",),
                                        nodes=("16nm",))
        ).design_points()


def test_corners_node_suffixed_points():
    """Node-suffixed corners (empty 'nodes' field) carry per-node
    capacities — the cross-node iso-area axis."""
    corners = sweep.DesignCorners(points=(
        "sram@3MB", "stt@7MB",
        "sram@3MB@7nm-scaled", "stt@4MB@7nm-scaled"))
    pts = corners.resolved_points()
    assert [(p.mem, p.capacity_mb, p.node.name, p.group) for p in pts] == [
        ("sram", 3.0, "16nm-finfet", ("16nm-finfet", 0)),
        ("stt", 7.0, "16nm-finfet", ("16nm-finfet", 0)),
        ("sram", 3.0, "7nm-scaled", ("7nm-scaled", 0)),
        ("stt", 4.0, "7nm-scaled", ("7nm-scaled", 0)),
    ]
    # the symbolic inverse reproduces the node-suffixed corner set
    assert sweep._symbolic_designs(pts) == corners
    # a suffixed set on ONE (non-anchor) node keeps the bare group
    one = sweep.DesignCorners(points=("sram@3MB@7nm", "stt@4MB@7nm"))
    assert all(p.group == 0 and p.node == TECH_7NM
               for p in one.resolved_points())


# ---------------------------------------------------------------------------
# JSON round-trip + memoized-run identity (the property)
# ---------------------------------------------------------------------------


def _assert_roundtrip_identity(sym: SymbolicSweepSpec):
    back = SymbolicSweepSpec.from_json(sym.to_json())
    assert back == sym
    assert back.resolve() == sym.resolve()
    assert back.run() is sym.run()          # same memoized result object


CNN_NAMES = tuple(f"cnn/{w}/{st}@b{b}"
                  for w in ("alexnet", "resnet18", "squeezenet")
                  for st, b in (("infer", 4), ("train", 8)))
LM_NAMES = ("lm/tinyllama-1.1b/decode_32k", "lm/rwkv6-3b/long_500k",
            "lm/hymba-1.5b/prefill_32k")
DESIGN_NAMES = ("sram@1MB", "stt@1MB", "sot@1MB",
                "sram@2MB", "stt@2MB", "sot@2MB")


@pytest.mark.parametrize("seed", range(5))
def test_json_roundtrip_resolves_to_memoized_result(seed):
    """Property: any registry-named spec survives to_json/from_json and
    the round-tripped spec's run() IS the original's memoized result."""
    rng = random.Random(seed)
    scen = rng.sample(CNN_NAMES + LM_NAMES, k=rng.randint(2, 5))
    sym = SymbolicSweepSpec(
        scenarios=tuple(scen),
        designs=DESIGN_NAMES,
        platforms=tuple(rng.sample(("gtx-1080ti", "tpu-v5e"),
                                   k=rng.randint(1, 2))),
        name=f"prop-{seed}")
    _assert_roundtrip_identity(sym)


def test_grid_and_corners_roundtrip():
    _assert_roundtrip_identity(SymbolicSweepSpec(
        scenarios=("cnn/alexnet/infer@b4",),
        designs=DesignGrid(mems=("sram", "stt"), capacities_mb=(1, 2),
                           nodes=("16nm-finfet", "7nm-scaled")),
        name="grid-rt"))
    _assert_roundtrip_identity(SymbolicSweepSpec(
        scenarios=("cnn/alexnet/infer@b4",),
        designs=sweep.DesignCorners(points=("sram@1MB", "stt@2MB"),
                                    group="iso"),
        name="corners-rt"))
    # tuple-valued group labels survive the JSON list round-trip hashable
    _assert_roundtrip_identity(SymbolicSweepSpec(
        scenarios=("cnn/alexnet/infer@b4",),
        designs=sweep.DesignCorners(points=("sram@1MB", "stt@2MB"),
                                    group=("iso", 1)),
        name="corners-tuple-rt"))


def test_from_spec_inverse():
    spec = isocap.spec()
    sym = SymbolicSweepSpec.from_spec(spec)
    assert sym.resolve() == spec
    # custom group labelings have no symbolic form
    odd = sweep.SweepSpec(
        name="odd",
        scenarios=sweep.workload_scenarios(
            (workloads.get("alexnet"),), ((False, 4),)),
        designs=(sweep.DesignPoint("sram", 2**20, group="a"),
                 sweep.DesignPoint("stt", 2**20, group="b")))
    with pytest.raises(ValueError):
        SymbolicSweepSpec.from_spec(odd)


def test_from_json_validation():
    good = json.loads(SymbolicSweepSpec(
        scenarios=("cnn/alexnet/infer@b4",),
        designs=("sram@3MB",)).to_json())
    with pytest.raises(ValueError):
        SymbolicSweepSpec.from_json({**good, "schema": "deepnvm.sweepspec/1"})
    with pytest.raises(ValueError):
        SymbolicSweepSpec.from_json({**good, "frobnicate": 1})
    missing = {k: v for k, v in good.items() if k != "designs"}
    with pytest.raises(ValueError):
        SymbolicSweepSpec.from_json(missing)
    with pytest.raises(ValueError):
        SymbolicSweepSpec.from_json(
            {**good, "designs": {"grid": {}, "corners": {}}})


# ---------------------------------------------------------------------------
# Golden specs: the JSON documents of the shipped analyses
# ---------------------------------------------------------------------------


def test_golden_isocap_resolves_to_analysis_spec():
    sym = sweep.load_spec(spec_path("isocap.json"))
    assert sym.resolve() == isocap.spec()
    assert sym.run() is sweep.run(isocap.spec())


def test_golden_dtco_resolves_to_analysis_spec():
    sym = sweep.load_spec(spec_path("dtco.json"))
    assert isinstance(sym.designs, DesignGrid)
    assert sym.resolve() == dtco.spec()
    assert sym.run() is sweep.run(dtco.spec())


def test_golden_dtco_isoarea_resolves_to_analysis_spec():
    sym = sweep.load_spec(spec_path("dtco_isoarea.json"))
    assert isinstance(sym.designs, DesignCorners)
    assert sym.resolve() == dtco.isoarea_spec()
    assert sym.run() is sweep.run(dtco.isoarea_spec())


def test_golden_lm_nvm_resolves_to_analysis_spec():
    sym = sweep.load_spec(spec_path("lm_nvm.json"))
    assert sym.resolve() == lm_nvm.spec()
    assert sym.run() is sweep.run(lm_nvm.spec())


def test_golden_files_are_normalized():
    """The checked-in documents are exactly what to_json emits (no drift
    between the files and the schema)."""
    for name in ("isocap.json", "dtco.json", "dtco_isoarea.json",
                 "lm_nvm.json", "mixed_cnn_lm.json"):
        text = open(spec_path(name)).read()
        assert SymbolicSweepSpec.from_json(text).to_json() == text, name


def test_golden_mixed_folds_cnn_and_lm_together():
    sym = sweep.load_spec(spec_path("mixed_cnn_lm.json"))
    before = workload_engine.evaluate_platforms.cache_info()
    res = sym.run()
    after = workload_engine.evaluate_platforms.cache_info()
    assert after.misses <= before.misses + 1   # one fold call for everything
    kinds = {("lm" if "/" in w else "cnn")
             for w, _, _ in res.scenario_labels}
    assert kinds == {"cnn", "lm"}
    # heterogeneous scenarios share the design axis and normalize per group
    assert res.norm_to().metric("edp").shape == (2, 5, 6)


# ---------------------------------------------------------------------------
# CLI: bit-for-bit reproduction + serve mode
# ---------------------------------------------------------------------------


def _csv_rows(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def _assert_csv_matches_rows(csv_path, rows):
    got = _csv_rows(csv_path)
    assert len(got) == len(rows)
    for parsed, ref in zip(got, rows):
        assert parsed.keys() == ref.keys()
        for k, v in ref.items():
            if isinstance(v, float):
                assert float(parsed[k]) == v, k     # exact, not approx
            else:
                assert parsed[k] == str(v), k


@pytest.mark.parametrize("golden,pyspec", [
    ("isocap.json", lambda: isocap.spec()),
    ("dtco.json", lambda: dtco.spec()),
    ("lm_nvm.json", lambda: lm_nvm.spec()),
])
def test_cli_reproduces_python_pipeline_bit_for_bit(golden, pyspec,
                                                    tmp_path):
    out = tmp_path / "rows.csv"
    sweep_cli.main(["run", spec_path(golden), "--csv", str(out)])
    _assert_csv_matches_rows(out, sweep.run(pyspec()).rows())


def test_cli_stdout_and_stdin(tmp_path, capsys, monkeypatch):
    text = open(spec_path("isocap.json")).read()
    monkeypatch.setattr("sys.stdin", io.StringIO(text))
    sweep_cli.main(["run", "-", "--no-norm"])
    outerr = capsys.readouterr()
    header = outerr.out.splitlines()[0]
    assert header.startswith("platform,workload,batch,stage,mem")
    assert "_x" not in header


def test_serve_answers_and_survives_bad_requests():
    doc = json.load(open(spec_path("isocap.json")))
    requests = [
        json.dumps(doc),
        json.dumps({"spec": doc, "want": ["rows", "pareto"]}),
        "{not json",
        json.dumps({"spec": {"schema": "bogus"}}),
        json.dumps({"spec": doc, "want": ["everything"]}),
    ]
    out = io.StringIO()
    served = sweep_cli.serve(io.StringIO("\n".join(requests) + "\n"), out)
    resp = [json.loads(line) for line in out.getvalue().splitlines()]
    assert served == len(requests)
    assert [r["ok"] for r in resp] == [True, True, False, False, False]
    assert resp[0]["summary"]["gtx-1080ti"]["sot"]["edp_reduction_max"] > 1
    rows = resp[1]["rows"]
    assert len(rows) == len(sweep.run(isocap.spec()).rows())
    json.dumps(resp)  # every response is JSON-serializable end to end


# ---------------------------------------------------------------------------
# Row serialization: stable group labels, CSV round-trip
# ---------------------------------------------------------------------------


def test_group_label_stability():
    assert sweep.group_label(3.0) == "3"
    assert sweep.group_label(0) == "0"
    assert sweep.group_label(1.5) == "1.5"
    assert sweep.group_label(("7nm-scaled", 3.0)) == "7nm-scaled/3"
    assert sweep.group_label("iso") == "iso"


@pytest.fixture(scope="module")
def dtco_result():
    return sweep.run(dtco.spec(nodes=(TECH_16NM, TECH_7NM)))


def test_rows_group_column_is_string(dtco_result):
    groups = {r["group"] for r in dtco_result.rows()}
    assert groups == {"16nm-finfet/3", "7nm-scaled/3"}
    single = sweep.run(isocap.spec())
    assert {r["group"] for r in single.rows()} == {"3"}


def test_csv_round_trip_pins_group_labels(dtco_result, tmp_path):
    path = tmp_path / "dtco.csv"
    dtco_result.to_csv(str(path), exact=True)
    parsed = _csv_rows(path)
    assert len(parsed) == len(dtco_result.rows())
    for got, ref in zip(parsed, dtco_result.rows()):
        assert got["group"] == ref["group"]
        assert "(" not in got["group"]          # no repr'd tuples
        assert float(got["edp_js"]) == ref["edp_js"]   # exact round-trip


# ---------------------------------------------------------------------------
# Query surface: filter / select
# ---------------------------------------------------------------------------


def test_filter_on_labeled_axes(dtco_result):
    view = dtco_result.filter(platform="gtx-1080ti", workload="alexnet",
                              stage="train", mem=("stt", "sot"),
                              node="7nm-scaled")
    assert len(view) == 2
    rows = view.rows()
    assert {r["mem"] for r in rows} == {"stt", "sot"}
    assert all(r["node"] == "7nm-scaled" and r["stage"] == "train"
               for r in rows)
    # chaining narrows further; TechNode values accepted for node
    assert len(view.filter(mem="stt")) == 1
    assert len(dtco_result.filter(node=TECH_7NM).design_ids) == 3
    # normalized values are those of the full result (baseline outside
    # the view still applies)
    full = {(r["mem"], r["node"]): r["edp_x"]
            for r in dtco_result.rows()
            if r["workload"] == "alexnet" and r["stage"] == "train"}
    for r in rows:
        assert r["edp_x"] == full[(r["mem"], r["node"])]


def test_filter_group_accepts_raw_and_label(dtco_result):
    """Raw tuple groups match directly (they are labels, not membership
    collections) and so do their stable string forms."""
    raw = dtco_result.filter(group=("7nm-scaled", 3.0))
    label = dtco_result.filter(group="7nm-scaled/3")
    assert len(raw.design_ids) == 3
    assert raw.design_ids == label.design_ids


def test_filter_predicates_and_errors(dtco_result):
    big = dtco_result.filter(batch=lambda b: b > 8)
    assert all(r["batch"] == 64 for r in big.rows())
    with pytest.raises(ValueError):
        dtco_result.filter(memory="stt")


def test_select(dtco_result):
    cols = dtco_result.filter(mem="sot", node="7nm-scaled",
                              workload="alexnet").select(
        "workload", "mem", "edp_x", include_dram=True)
    assert len(cols) == 2
    for workload, mem, edp_x in cols:
        assert (workload, mem) == ("alexnet", "sot")
        assert edp_x < 1.0
    with pytest.raises(ValueError):
        dtco_result.select("workload", "nope")


def test_metric_slice_matches_full(dtco_result):
    import numpy as np
    view = dtco_result.filter(mem="stt")
    full = dtco_result.metric("energy")
    ids = view.design_ids
    assert np.array_equal(view.metric("energy"), full[:, :, list(ids)])

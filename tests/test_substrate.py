"""Substrate tests: checkpointing, data, compression, schedules, faults."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.data import DataConfig, SyntheticTokens  # noqa: E402
from repro.distributed.compression import (EFCompressor,  # noqa: E402
                                           dequantize_int8,
                                           quantize_int8, topk_sparsify)
from repro.distributed.fault import RestartPolicy, StragglerDetector  # noqa: E402
from repro.optim.schedules import cosine, wsd  # noqa: E402


class TestCheckpoint:
    def tree(self, v=0.0):
        return {"a": jnp.full((4, 3), v), "b": {"c": jnp.arange(5.0) + v}}

    def test_roundtrip_and_keep_k(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        for s in (10, 20, 30):
            m.save(s, self.tree(s), blocking=True)
        assert m.latest_step() == 30
        assert sorted(m._complete_steps()) == [20, 30]  # gc'd step 10
        step, t = m.restore_latest(self.tree())
        assert step == 30
        np.testing.assert_array_equal(t["a"], np.full((4, 3), 30.0))

    def test_async_save(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(5, self.tree(5), blocking=False)
        m.wait()
        assert m.latest_step() == 5

    def test_corrupt_checkpoint_skipped(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=5)
        m.save(1, self.tree(1), blocking=True)
        m.save(2, self.tree(2), blocking=True)
        # corrupt the newest: delete a leaf
        os.remove(os.path.join(str(tmp_path), "step_0000000002",
                               "leaf_00000.npy"))
        step, t = m.restore_latest(self.tree())
        assert step == 1

    def test_partial_save_never_visible(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        os.makedirs(os.path.join(str(tmp_path), "step_0000000009.tmp"))
        assert m.latest_step() is None

    def test_shape_mismatch_rejected(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(1, self.tree(), blocking=True)
        bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.zeros(5)}}
        with pytest.raises(ValueError):
            m.restore(1, bad)


class TestData:
    def test_deterministic_addressing(self):
        cfg = DataConfig(vocab=97, seq_len=16, global_batch=4)
        d1, d2 = SyntheticTokens(cfg), SyntheticTokens(cfg)
        b1, b2 = d1.batch(7), d2.batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(d1.batch(8)["tokens"], b1["tokens"])

    def test_host_sharding_partitions_global_batch(self):
        cfg = DataConfig(vocab=97, seq_len=8, global_batch=8, n_hosts=1)
        full = SyntheticTokens(cfg).batch(3)
        # two hosts half the batch each; content depends on host_index
        h0 = SyntheticTokens(DataConfig(vocab=97, seq_len=8, global_batch=8,
                                        n_hosts=2, host_index=0)).batch(3)
        h1 = SyntheticTokens(DataConfig(vocab=97, seq_len=8, global_batch=8,
                                        n_hosts=2, host_index=1)).batch(3)
        assert h0["tokens"].shape[0] == h1["tokens"].shape[0] == 4
        assert not np.array_equal(h0["tokens"], h1["tokens"])
        assert full["tokens"].shape[0] == 8

    def test_labels_are_next_tokens(self):
        cfg = DataConfig(vocab=31, seq_len=12, global_batch=2)
        b = SyntheticTokens(cfg).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetch_matches_direct(self):
        cfg = DataConfig(vocab=31, seq_len=8, global_batch=2)
        data = SyntheticTokens(cfg)
        it = data.prefetch(start_step=2)
        step, batch = next(it)
        assert step == 2
        np.testing.assert_array_equal(batch["tokens"],
                                      data.batch(2)["tokens"])


class TestCompression:
    @given(st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_int8_roundtrip_bounded_error(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
        q, s = quantize_int8(x)
        err = jnp.abs(dequantize_int8(q, s) - x)
        assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-6

    def test_topk_keeps_largest(self):
        x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
        y = topk_sparsify(x, 0.4)
        np.testing.assert_array_equal(np.asarray(y),
                                      [0.0, -5.0, 0.0, 3.0, 0.0])

    def test_error_feedback_preserves_sum(self):
        """EF invariant: compressed + error == original (exactly)."""
        comp = EFCompressor(kind="int8")
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (32,))}
        e = comp.init(g)
        out, e2 = comp(g, e)
        np.testing.assert_allclose(np.asarray(out["w"] + e2["w"]),
                                   np.asarray(g["w"]), rtol=1e-6, atol=1e-6)


class TestFault:
    def test_straggler_detection(self):
        d = StragglerDetector(warmup=5)
        flags = [d.observe(1.0 + 0.01 * (i % 3)) for i in range(20)]
        assert not any(flags)
        assert d.observe(10.0)  # clear outlier

    def test_restart_policy_bounded(self):
        p = RestartPolicy(max_restarts=2, window_s=100)
        assert p.should_restart(now=0)
        p.record(now=0)
        assert p.should_restart(now=1)
        p.record(now=1)
        assert not p.should_restart(now=2)
        assert p.should_restart(now=200)  # window expired


class TestSchedules:
    def test_wsd_shape(self):
        lr = [float(wsd(s, peak=1.0, warmup=10, total=100)) for s in
              (0, 9, 50, 89, 95, 100)]
        assert lr[0] < lr[1] <= 1.0
        assert lr[2] == pytest.approx(1.0)       # stable plateau
        assert lr[3] == pytest.approx(1.0, abs=0.05)
        assert lr[4] < 0.5                        # sharp decay phase
        assert lr[5] == pytest.approx(0.01, rel=0.3)

    def test_cosine_monotone_after_peak(self):
        vals = [float(cosine(s, peak=1.0, warmup=10, total=100))
                for s in range(10, 100, 10)]
        assert all(a >= b for a, b in zip(vals, vals[1:]))

"""Tests for the repro.analysis static-analysis suite.

Each rule gets a planted-violation fixture that must fire and a
corrected twin that must stay silent; on top of that the suppression
markers, the baseline round-trip, and the CLI exit codes are exercised,
and the analyzer is required to run clean over ``src/repro/core``.

The DNVM001 wrapper test replays the PR-4 incident: ``design_table``
grew a ``nodes`` parameter but kept forwarding into its memoized worker
without it, so every node silently shared the 16 nm tables.  Reverting
that fix must be caught by the analyzer, not by luck.
"""

from __future__ import annotations

import os
import textwrap
from pathlib import Path

import pytest

from repro.analysis import __main__ as cli
from repro.analysis import common, driver

REPO_ROOT = Path(__file__).resolve().parents[1]


def run_source(tmp_path, source, rules=None, name="sample.py",
               baseline=None):
    path = tmp_path / name
    path.write_text(textwrap.dedent(source))
    return driver.run_paths([str(path)], rules=rules, baseline=baseline)


def messages(result):
    return [f"{f.rule} {f.message}" for f in result.active]


# ---------------------------------------------------------------------------
# DNVM001 — memo-key completeness


class TestMemoKeys:
    def test_varying_global_read_fires(self, tmp_path):
        res = run_source(tmp_path, """
            import functools

            counter = 0

            def bump():
                global counter
                counter += 1

            @functools.lru_cache(maxsize=None)
            def lookup(x):
                return x + counter
            """, rules=["DNVM001"])
        assert len(res.active) == 1
        assert "mutable module state 'counter'" in res.active[0].message

    def test_constant_registry_read_is_silent(self, tmp_path):
        res = run_source(tmp_path, """
            import functools

            TABLE = {"stt": 1.0, "sot": 2.0}

            @functools.lru_cache(maxsize=None)
            def lookup(mem):
                return TABLE[mem]
            """, rules=["DNVM001"])
        assert res.active == []

    def test_mutable_default_fires_and_tuple_twin_is_silent(self, tmp_path):
        fires = run_source(tmp_path, """
            import functools

            @functools.cache
            def grid(caps=[1024, 2048]):
                return sum(caps)
            """, rules=["DNVM001"])
        assert len(fires.active) == 1
        assert "mutable default" in fires.active[0].message

        silent = run_source(tmp_path, """
            import functools

            @functools.cache
            def grid(caps=(1024, 2048)):
                return sum(caps)
            """, rules=["DNVM001"], name="twin.py")
        assert silent.active == []

    def test_pr4_node_blind_wrapper_fires(self, tmp_path):
        """Reverting the PR-4 design_table fix must be caught: the
        wrapper takes ``nodes`` but never forwards it into the key."""
        res = run_source(tmp_path, """
            import functools

            @functools.lru_cache(maxsize=None)
            def _design_table_cached(mems, capacities_bytes):
                return (mems, capacities_bytes)

            def design_table(mems, capacities_bytes, nodes=None):
                return _design_table_cached(tuple(mems),
                                            tuple(capacities_bytes))
            """, rules=["DNVM001"])
        assert len(res.active) == 1
        msg = res.active[0].message
        assert "'nodes' is never read" in msg
        assert "PR-4 design_table bug class" in msg

    def test_forwarding_wrapper_twin_is_silent(self, tmp_path):
        res = run_source(tmp_path, """
            import functools

            @functools.lru_cache(maxsize=None)
            def _design_table_cached(nodes, mems, capacities_bytes):
                return (nodes, mems, capacities_bytes)

            def design_table(mems, capacities_bytes, nodes=None):
                return _design_table_cached(nodes, tuple(mems),
                                            tuple(capacities_bytes))
            """, rules=["DNVM001"])
        assert res.active == []

    def test_real_design_table_wrapper_forwards_every_param(self):
        """The live engine.py wrapper stays key-complete."""
        res = driver.run_paths(
            [str(REPO_ROOT / "src/repro/core/engine.py")],
            rules=["DNVM001"])
        assert messages(res) == []


# ---------------------------------------------------------------------------
# DNVM002 — jit/retrace discipline


class TestRetrace:
    def test_traced_branch_fires(self, tmp_path):
        res = run_source(tmp_path, """
            import jax

            @jax.jit
            def kernel(x, fast_path):
                if fast_path:
                    return x * 2.0
                return x
            """, rules=["DNVM002"])
        assert len(res.active) == 1
        assert "branches on traced argument 'fast_path'" in \
            res.active[0].message

    def test_static_argnames_twin_is_silent(self, tmp_path):
        res = run_source(tmp_path, """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("fast_path",))
            def kernel(x, fast_path):
                if fast_path:
                    return x * 2.0
                return x
            """, rules=["DNVM002"])
        assert res.active == []

    def test_jit_call_assignment_with_static_argnums(self, tmp_path):
        res = run_source(tmp_path, """
            import jax

            def kernel(x, mode):
                if mode:
                    return x * 2.0
                return x

            fast = jax.jit(kernel, static_argnums=(1,))
            """, rules=["DNVM002"])
        assert res.active == []

    def test_varying_global_capture_fires(self, tmp_path):
        res = run_source(tmp_path, """
            import jax

            scale = 1.0

            def set_scale(s):
                global scale
                scale = s

            @jax.jit
            def kernel(x):
                return x * scale
            """, rules=["DNVM002"])
        assert len(res.active) == 1
        assert "captures mutable module state 'scale'" in \
            res.active[0].message

    def test_dtype_narrowing_fires_only_under_x64(self, tmp_path):
        src = """
            import jax
            import jax.numpy as jnp
            {x64}

            @jax.jit
            def kernel(x):
                return x.astype(jnp.float32)
            """
        fires = run_source(
            tmp_path, src.format(x64="from jax.experimental import "
                                     "enable_x64"),
            rules=["DNVM002"])
        assert len(fires.active) == 1
        assert "narrows the enable_x64 float64 contract" in \
            fires.active[0].message

        silent = run_source(tmp_path, src.format(x64=""),
                            rules=["DNVM002"], name="no_x64.py")
        assert silent.active == []


# ---------------------------------------------------------------------------
# DNVM003 — unit consistency


class TestUnits:
    def test_seconds_plus_joules_fires(self, tmp_path):
        res = run_source(tmp_path, """
            def edp(read_latency_s, read_energy_j):
                return read_latency_s + read_energy_j
            """, rules=["DNVM003"])
        assert len(res.active) == 1
        assert "unit mismatch" in res.active[0].message

    def test_seconds_plus_seconds_is_silent(self, tmp_path):
        res = run_source(tmp_path, """
            def total(read_latency_s, write_latency_s):
                return read_latency_s + write_latency_s
            """, rules=["DNVM003"])
        assert res.active == []

    def test_farads_times_ohms_binds_to_seconds(self, tmp_path):
        """RC products are the bread and butter of cachemodel.py — the
        F*ohm -> s identity must be understood, not flagged."""
        res = run_source(tmp_path, """
            def rc_delay(c_bitline_f, r_driver_ohm):
                tau_s = c_bitline_f * r_driver_ohm
                return tau_s
            """, rules=["DNVM003"])
        assert res.active == []

    def test_keyword_unit_mismatch_fires(self, tmp_path):
        res = run_source(tmp_path, """
            def record(energy_j):
                return energy_j

            def caller(leakage_w):
                return record(energy_j=leakage_w)
            """, rules=["DNVM003"])
        assert len(res.active) == 1
        assert "keyword 'energy_j'" in res.active[0].message

    def test_scaled_seconds_stay_seconds(self, tmp_path):
        res = run_source(tmp_path, """
            def slowdown(read_latency_s):
                padded_s = 1.15 * read_latency_s
                return padded_s
            """, rules=["DNVM003"])
        assert res.active == []


# ---------------------------------------------------------------------------
# DNVM004 — lock discipline


class TestLocks:
    def test_unguarded_counter_fires(self, tmp_path):
        res = run_source(tmp_path, """
            import threading

            class Service:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.batches = 0

                def tick(self):
                    self.batches += 1
            """, rules=["DNVM004"])
        assert len(res.active) == 1
        assert "mutates 'self.batches' outside" in res.active[0].message

    def test_guarded_twin_is_silent(self, tmp_path):
        res = run_source(tmp_path, """
            import threading

            class Service:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.batches = 0

                def tick(self):
                    with self._lock:
                        self.batches += 1
            """, rules=["DNVM004"])
        assert res.active == []

    def test_any_owned_lock_counts(self, tmp_path):
        """Guardedness, not lock-to-field assignment: holding the
        class's condition variable is as good as holding its lock."""
        res = run_source(tmp_path, """
            import threading

            class Service:
                def __init__(self):
                    self._lock = threading.Lock()
                    self._cv = threading.Condition(self._lock)
                    self.pending = {}

                def enqueue(self, key, item):
                    with self._cv:
                        self.pending[key] = item
            """, rules=["DNVM004"])
        assert res.active == []

    def test_module_global_outside_lock_fires(self, tmp_path):
        res = run_source(tmp_path, """
            import threading

            _registry_lock = threading.Lock()
            _registry = None

            def install(r):
                global _registry
                _registry = r
            """, rules=["DNVM004"])
        assert len(res.active) == 1
        assert "global '_registry' assigned outside" in \
            res.active[0].message

    def test_lockless_class_is_out_of_scope(self, tmp_path):
        res = run_source(tmp_path, """
            class Accumulator:
                def __init__(self):
                    self.total = 0.0

                def add(self, x):
                    self.total += x
            """, rules=["DNVM004"])
        assert res.active == []


# ---------------------------------------------------------------------------
# suppressions, baseline, driver, CLI


PLANTED = """
    import functools

    state = {{}}

    def poke(k, v):
        state[k] = v

    @functools.cache
    def lookup(k):{marker}
        return state.get(k)
    """


class TestSuppression:
    def test_marker_suppresses_own_and_next_line(self, tmp_path):
        res = run_source(
            tmp_path,
            PLANTED.format(marker="  # dnvm: ok(DNVM001, fixture)"),
            rules=["DNVM001"])
        assert res.active == []
        assert res.suppressed == 1

    def test_without_marker_fires(self, tmp_path):
        res = run_source(tmp_path, PLANTED.format(marker=""),
                         rules=["DNVM001"])
        assert len(res.active) == 1

    def test_malformed_marker_is_a_finding(self, tmp_path):
        res = run_source(tmp_path, """
            x = 1  # dnvm: ok(DNVM001)
            """)
        assert len(res.active) == 1
        assert res.active[0].rule == "DNVM000"
        assert "non-empty reason" in res.active[0].message

    def test_wrong_rule_marker_does_not_suppress(self, tmp_path):
        res = run_source(
            tmp_path,
            PLANTED.format(marker="  # dnvm: ok(DNVM004, wrong rule)"),
            rules=["DNVM001"])
        assert len(res.active) == 1


class TestBaseline:
    def test_round_trip(self, tmp_path):
        src_path = tmp_path / "planted.py"
        src_path.write_text(textwrap.dedent(PLANTED.format(marker="")))
        first = driver.run_paths([str(src_path)], rules=["DNVM001"])
        assert len(first.active) == 1

        baseline_path = tmp_path / "baseline.txt"
        common.write_baseline(str(baseline_path), first.findings)
        accepted = common.load_baseline(str(baseline_path))
        assert len(accepted) == 1

        second = driver.run_paths([str(src_path)], rules=["DNVM001"],
                                  baseline=accepted)
        assert second.active == []
        assert second.baselined == 1

    def test_keys_survive_line_shifts(self, tmp_path):
        src_path = tmp_path / "planted.py"
        src_path.write_text(textwrap.dedent(PLANTED.format(marker="")))
        baseline = {f.baseline_key() for f in driver.run_paths(
            [str(src_path)], rules=["DNVM001"]).findings}

        shifted = "# a new comment line\n# another\n" + \
            textwrap.dedent(PLANTED.format(marker=""))
        src_path.write_text(shifted)
        res = driver.run_paths([str(src_path)], rules=["DNVM001"],
                               baseline=baseline)
        assert res.active == []
        assert res.baselined == 1

    def test_missing_baseline_file_is_empty(self, tmp_path):
        assert common.load_baseline(str(tmp_path / "absent.txt")) == set()


class TestDriver:
    def test_syntax_error_becomes_dnvm000(self, tmp_path):
        res = run_source(tmp_path, "def broken(:\n")
        assert len(res.active) == 1
        assert res.active[0].rule == "DNVM000"

    def test_counts_by_rule(self, tmp_path):
        res = run_source(tmp_path, PLANTED.format(marker=""),
                         rules=["DNVM001"])
        assert res.counts["DNVM001"] == 1

    def test_clean_over_repro_core(self):
        """The shipped core must analyze clean with no baseline at all."""
        res = driver.run_paths([str(REPO_ROOT / "src/repro/core")])
        assert messages(res) == []
        assert res.files >= 10


class TestCLI:
    def test_strict_exit_codes(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent(PLANTED.format(marker="")))
        assert cli.main([str(bad), "--strict", "--no-baseline"]) == 1
        out = capsys.readouterr().out
        assert "DNVM001" in out

        good = tmp_path / "good.py"
        good.write_text("x = 1\n")
        assert cli.main([str(good), "--strict", "--no-baseline"]) == 0

    def test_write_baseline_then_strict_passes(self, tmp_path,
                                               monkeypatch):
        bad = tmp_path / "bad.py"
        bad.write_text(textwrap.dedent(PLANTED.format(marker="")))
        monkeypatch.chdir(tmp_path)
        assert cli.main([str(bad), "--write-baseline"]) == 0
        assert os.path.exists(tmp_path / common.BASELINE_DEFAULT)
        assert cli.main([str(bad), "--strict"]) == 0

    def test_unknown_rule_is_usage_error(self, tmp_path):
        with pytest.raises(SystemExit) as e:
            cli.main([str(tmp_path), "--rules", "DNVM999"])
        assert e.value.code == 2

    def test_repo_baseline_covers_src_repro(self, monkeypatch):
        """The acceptance gate itself: strict run over src/repro with the
        checked-in baseline exits 0."""
        monkeypatch.chdir(REPO_ROOT)
        assert cli.main(["src/repro", "--strict"]) == 0

"""Parity tests: the batched workload engine vs the scalar traffic path.

The workload engine re-expresses the architecture-layer fold
(``traffic.runtime`` / ``traffic.energy`` / ``TrafficStats.dram_tx``) as
one jitted [scenario] x [design] computation; these tests pin the two
implementations together across every paper workload x {inference,
training} x memory technology x scaling capacity, plus the batched DRAM
miss-curve, the normalized-metric helpers the analyses consume, and the
padding/memoization behavior of the pack.
"""

import numpy as np
import pytest

from repro.core import engine, isocap, traffic, workload_engine
from repro.core.isocap import INFER_BATCH, TRAIN_BATCH, MEMS
from repro.core.scaling import CAPACITIES_MB
from repro.core.workloads import paper_workloads

REL = 1e-12  # float64 agreement between the scalar and batched paths
REPORT_FIELDS = ("runtime_s", "dyn_read_j", "dyn_write_j", "leak_j", "dram_j")

STAGES = ((False, INFER_BATCH), (True, TRAIN_BATCH))


@pytest.fixture(scope="module")
def stats_list():
    """All paper workloads x {inference, training} scenarios."""
    return [workload_engine.stats_for(w, batch, training)
            for w in paper_workloads().values()
            for training, batch in STAGES]


@pytest.fixture(scope="module")
def designs():
    """EDAP-tuned designs for all MEMS at all scaling capacities."""
    caps = tuple(int(c * 2**20) for c in CAPACITIES_MB)
    table = engine.design_table(tuple(MEMS), caps)
    return tuple(table.tuned(m, c) for c in caps for m in MEMS)


@pytest.fixture(scope="module")
def table(stats_list, designs):
    return workload_engine.evaluate(stats_list, designs)


def test_reports_match_scalar_energy(stats_list, designs, table):
    """Every [scenario, design] cell equals the scalar traffic.energy."""
    for i, stats in enumerate(stats_list):
        for j, design in enumerate(designs):
            ref = traffic.energy(stats, design)
            rep = table.report(i, j)
            for f in REPORT_FIELDS:
                assert getattr(rep, f) == pytest.approx(
                    getattr(ref, f), rel=REL), \
                    f"{table.scenarios[i]}/{design.mem}@{design.capacity_mb}MB: {f}"
            for include_dram in (False, True):
                assert float(table.total_j(include_dram)[i, j]) == \
                    pytest.approx(ref.total_j(include_dram), rel=REL)
                assert float(table.edp(include_dram)[i, j]) == \
                    pytest.approx(ref.edp(include_dram), rel=REL)


def test_runtime_matches_scalar_runtime(stats_list, designs, table):
    """Both include_dram runtime variants equal traffic.runtime."""
    for i, stats in enumerate(stats_list):
        for j, design in enumerate(designs):
            assert float(table.runtime_s[i, j]) == pytest.approx(
                traffic.runtime(stats, design, include_dram=True), rel=REL)
            assert float(table.runtime_nodram_s[i, j]) == pytest.approx(
                traffic.runtime(stats, design, include_dram=False), rel=REL)


def test_l2_transactions_match_scalar(stats_list, table):
    for i, stats in enumerate(stats_list):
        assert float(table.l2_read_tx[i]) == pytest.approx(
            stats.l2_read_tx, rel=REL)
        assert float(table.l2_write_tx[i]) == pytest.approx(
            stats.l2_write_tx, rel=REL)
        assert float(table.read_write_ratio[i]) == pytest.approx(
            stats.read_write_ratio, rel=REL)


def test_dram_tx_curve_matches_scalar(stats_list):
    """Batched miss-curve (Fig. 6 sweep) == per-capacity scalar dram_tx."""
    caps = [int(c * 2**20) for c in (1, 3, 6, 7, 10, 32)]
    tx = workload_engine.dram_tx(stats_list, caps)
    for i, stats in enumerate(stats_list):
        for k, cap in enumerate(caps):
            assert float(tx[i, k]) == pytest.approx(stats.dram_tx(cap),
                                                    rel=REL)


def test_norm_matches_isocap_rows(stats_list):
    """WorkloadTable.norm equals the scalar IsoCapRow.norm convention."""
    designs3 = tuple(isocap.designs_at(3).values())
    table = workload_engine.evaluate(stats_list, designs3)
    rows = isocap.analyze()
    assert len(rows) == len(stats_list)
    for i, row in enumerate(rows):
        assert table.scenarios[i] == (row.workload, row.batch, row.training)
        for mem in ("stt", "sot"):
            for metric in ("dyn", "leak", "energy", "runtime"):
                assert float(table.norm(metric, mem)[i]) == pytest.approx(
                    row.norm(metric, mem), rel=REL)
            assert float(table.norm("edp", mem, include_dram=True)[i]) == \
                pytest.approx(row.norm("edp", mem, True), rel=REL)


def test_padding_invariance(stats_list, designs, table):
    """A scenario evaluated alone (different pad width) matches the full
    cross product — padding contributes nothing to any fold."""
    sub = workload_engine.evaluate(stats_list[:1], designs[:3])
    for j in range(3):
        for f in REPORT_FIELDS:
            assert getattr(sub.report(0, j), f) == pytest.approx(
                getattr(table.report(0, j), f), rel=REL)


def test_evaluate_memoized(stats_list, designs, table):
    assert workload_engine.evaluate(stats_list, designs) is table


def test_index_errors(table):
    with pytest.raises(ValueError):
        table.design_index("sram", 999)
    with pytest.raises(ValueError):
        table.design_index("sram")  # several capacities: ambiguous
    with pytest.raises(ValueError):
        table.scenario_index("no-such-workload", 1, False)
    with pytest.raises(ValueError):
        table.reports(0)  # 18 designs are not memory-unique


def test_design_index_duplicate_mem_capacity_raises(stats_list):
    """Regression: duplicate (mem, capacity) designs — e.g. the same
    corner at two technology nodes — must raise even when capacity_bytes
    is given, not silently return the first match."""
    from repro.core.tech import TECH_7NM
    cap = 3 * 2**20
    d16 = engine.design_table(("sram",), (cap,)).tuned("sram", cap)
    d7 = engine.design_table(("sram",), (cap,),
                             nodes=TECH_7NM).tuned("sram", cap)
    assert d16 != d7
    dup = workload_engine.evaluate(stats_list[:1], (d16, d7))
    with pytest.raises(ValueError, match="several designs"):
        dup.design_index("sram", cap)
    with pytest.raises(ValueError):
        dup.design_index("sram")


def test_stream_batch_mask_counts(stats_list):
    batch = workload_engine.pack(stats_list)
    for i, stats in enumerate(stats_list):
        assert int(batch.mask[i].sum()) == len(stats.streams)
        # padding rows carry zero bytes and infinite reuse distance
        assert not batch.bytes_total[i, ~batch.mask[i]].any()
        assert np.isinf(batch.reuse_distance[i, ~batch.mask[i]]).all()

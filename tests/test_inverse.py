"""Tests for the inverse-design subsystem (repro/inverse/).

Families:

  grad       finite-difference checks (rel err <= 1e-5) of the loss
             gradient on every exposed leaf at 16 nm and 7 nm;
  cell       the relaxed soft bitcell at HARD_TEMP equals the standard
             ``characterize`` cell bit-for-bit (softmin hardening is
             exact, not approximate);
  recover    softmin -> argmin consistency: hardened center evaluation
             recovers the grid-argmin winner on the golden isocap and
             dtco_isoarea specs, same (mem, capacity, node, org) corner;
  wall       the STT scaling-wall penalty: ~0 with 16 nm overdrive
             headroom, large and finite (with finite gradients) at the
             extrapolated 2 nm node;
  solve      the end-to-end acceptance: gradient descent finds an
             off-grid design with strictly lower EDP than every grid
             corner at equal area budget, verified through the standard
             (non-relaxed) engine path at <= 1e-12 parity;
  problem    deepnvm.inverse/1 round-trip, strict unknown-field
             rejection, result-document serializability;
  sens       elasticity tables: finite, nonzero, correctly labeled.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro import inverse
from repro.core import bitcell, tech
from repro.core.sweep import SymbolicSweepSpec
from repro.inverse import bounds as bounds_mod
from repro.inverse import relax, sensitivity

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SPECS = os.path.join(ROOT, "specs")

# Small two-node grid exercising both flavors at 16 nm and 7 nm: the
# gradient tests cover every leaf of all four (flavor, node) groups.
TWO_NODE_DOC = {
    "schema": "deepnvm.sweepspec/2", "name": "inv-two-node",
    "scenarios": ["cnn/alexnet/infer@b4", "cnn/resnet18/train@b64"],
    "designs": ["sram@3MB", "stt@3MB", "sot@3MB",
                "stt@3MB@7nm-scaled", "sot@3MB@7nm-scaled"],
    "platforms": ["gtx-1080ti"], "baseline_mem": "sram",
}


@pytest.fixture(scope="module")
def two_node_lowered():
    prob = inverse.InverseProblem(
        sweep=SymbolicSweepSpec.from_json(TWO_NODE_DOC), objective="edp")
    with enable_x64():
        yield relax.lower(prob)


@pytest.fixture(scope="module")
def isocap_problem():
    return inverse.InverseProblem(
        sweep=SymbolicSweepSpec.load(os.path.join(SPECS, "isocap.json")),
        objective="edp", name="isocap-inv")


# ---------------------------------------------------------------------------
# grad: finite differences on every leaf, 16 nm and 7 nm
# ---------------------------------------------------------------------------


def test_gradient_matches_finite_differences_on_every_leaf(
        two_node_lowered):
    low = two_node_lowered
    names = [f"{g.flavor}@{g.node.name}:{f}"
             for g in low.groups for f in bounds_mod.LEAF_FIELDS]
    assert len(names) == 4 * bounds_mod.N_LEAVES  # both flavors x nodes
    # a seeded off-center point: the SOT anchor has ic0_set == ic0_reset
    # exactly, which parks min(od_set, od_reset) on its kink — a generic
    # point breaks the tie by far more than the FD step
    rng = np.random.default_rng(7)
    theta = low.theta0 + rng.uniform(-0.02, 0.02, low.theta0.size)
    with enable_x64():
        temp = 0.5
        loss = jax.jit(low.loss)
        grad = np.asarray(jax.jit(jax.grad(low.loss))(theta, temp))
        assert np.all(np.isfinite(grad))
        h = 1e-5
        for i, name in enumerate(names):
            e = np.zeros_like(theta)
            e[i] = h
            fd = (float(loss(theta + e, temp))
                  - float(loss(theta - e, temp))) / (2.0 * h)
            scale = max(abs(fd), abs(float(grad[i])), 1e-3)
            assert abs(fd - grad[i]) / scale <= 1e-5, \
                f"{name}: fd={fd:.9e} grad={grad[i]:.9e}"


def test_gradient_is_nonzero_on_every_leaf(two_node_lowered):
    # every exposed leaf must actually steer the loss (dead axes would
    # mean a leaf that never reaches a PPA expression)
    low = two_node_lowered
    with enable_x64():
        grad = np.asarray(jax.grad(low.loss)(low.theta0, 0.5))
    assert np.count_nonzero(grad) == grad.size


# ---------------------------------------------------------------------------
# cell: hardened soft cell == standard characterization, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("flavor", ["stt", "sot"])
@pytest.mark.parametrize("node", [tech.TECH_16NM,
                                  tech.scaled_node(7e-9)])
def test_hard_soft_cell_matches_characterize(flavor, node):
    # at HARD_TEMP the softmax weights are exactly one-hot, so the only
    # discrepancy vs the standard cell is the exp(ln(anchor)) round-trip
    # of the theta packing: a few ulps per component, nothing more
    groups = bounds_mod.leaf_groups([(flavor, 3 << 20, node)])
    theta = bounds_mod.pack_theta(groups)
    with enable_x64():
        cell, od_best = relax.soft_cell(jnp.asarray(theta), groups[0],
                                        relax.HARD_TEMP)
        cell = np.asarray(cell)
    want = bitcell.characterize(flavor, node).as_array()
    assert float(od_best) > 0.0
    np.testing.assert_allclose(cell, want, rtol=1e-13)


# ---------------------------------------------------------------------------
# recover: golden-spec softmin -> argmin consistency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec_name", ["isocap.json", "dtco_isoarea.json"])
def test_center_recovery_matches_grid_argmin(spec_name):
    prob = inverse.InverseProblem(
        sweep=SymbolicSweepSpec.load(os.path.join(SPECS, spec_name)),
        objective="edp", name=spec_name)
    with enable_x64():
        low = relax.lower(prob)
        grid = inverse.grid_argmin(prob, low)
        rec = inverse.recover_corner(prob, low)
    assert rec["corner"] == grid["corner"]
    assert rec["value"] == pytest.approx(grid["value"], rel=1e-12)


# ---------------------------------------------------------------------------
# wall: the scaling-wall penalty at 16 nm vs the extrapolated 2 nm node
# ---------------------------------------------------------------------------


def test_scaling_wall_penalty_regression_at_2nm():
    n2 = tech.scaled_node(2e-9, allow_extrapolation=True)
    g2 = bounds_mod.leaf_groups([("stt", 3 << 20, n2)])[0]
    g16 = bounds_mod.leaf_groups([("stt", 3 << 20, tech.TECH_16NM)])[0]
    with enable_x64():
        _, od2 = relax.soft_cell(
            jnp.asarray(bounds_mod.pack_theta((g2,))), g2, 0.5)
        _, od16 = relax.soft_cell(
            jnp.asarray(bounds_mod.pack_theta((g16,))), g16, 0.5)

        def penalty(od):
            return float(relax.LAMBDA_WALL
                         * jax.nn.softplus(-od / relax.WALL_SCALE))

        # 2 nm STT is past the wall (negative best overdrive): large,
        # finite penalty; 16 nm has headroom: near-zero penalty
        assert float(od2) < 0.0 < float(od16)
        assert penalty(od2) > 5.0
        assert penalty(od16) < 1.0
        assert np.isfinite(penalty(od2))

        # the wall is differentiable at 2 nm: the optimizer can feel it
        def wall_loss(theta):
            _, od = relax.soft_cell(theta, g2, 0.5)
            return relax.LAMBDA_WALL * jax.nn.softplus(
                -od / relax.WALL_SCALE)

        grad = np.asarray(jax.grad(wall_loss)(
            jnp.asarray(bounds_mod.pack_theta((g2,)))))
        assert np.all(np.isfinite(grad))
        assert np.any(grad != 0.0)


# ---------------------------------------------------------------------------
# solve: the off-grid acceptance (strict win + standard-path parity)
# ---------------------------------------------------------------------------


def test_solve_beats_every_grid_corner_at_equal_area(isocap_problem):
    import dataclasses
    prob = dataclasses.replace(isocap_problem, starts=1, iters=60)
    res = inverse.solve(prob)
    # strictly lower EDP than the best grid corner (hence every corner)
    # under the same iso-area budget
    assert res.best_value < res.grid_best_value
    assert res.gain_vs_grid > 0.0
    assert res.area_mm2 <= res.area_budget_mm2 * (1.0 + 1e-9)
    # the relaxed optimum is backed by the standard (non-relaxed) path
    assert res.parity_rel_err <= 1e-12
    assert res.standard_value == pytest.approx(res.best_value, rel=1e-12)
    # the converged leaves moved off the grid anchors
    anchors = {g.key: dict(zip(bounds_mod.LEAF_FIELDS, g.centers))
               for g in relax.lower(prob).groups}
    moved = [f for key, leaves in res.leaves.items()
             for f, v in leaves.items()
             if abs(v - anchors[key][f]) / anchors[key][f] > 1e-3]
    assert moved, "solver returned the anchor design"
    # result document is JSON-serializable
    json.dumps(res.to_doc())
    assert "inverse" in res.summary()


def test_target_mode_drives_objective_to_target(two_node_lowered):
    # target-hitting: ask for an EDP 10% above the center value and check
    # the loss is the squared log residual (zero iff on target)
    low = two_node_lowered
    with enable_x64():
        import dataclasses
        obj, area, _ = low.objective_matrix(low.theta0)
        ki, oi = low.masked_argmin(np.asarray(obj), np.asarray(area))
        target = float(np.asarray(obj)[ki, oi]) * 1.1
        prob_t = dataclasses.replace(low.problem, target=target,
                                     area_budget_mm2=None)
        low_t = relax.lower(prob_t)
        loss_t = float(low_t.loss(low_t.theta0, relax.HARD_TEMP))
        # the loss is the squared log residual of the softmin objective
        # vs the target plus the (theta-only) scaling-wall penalties
        soft = float(np.asarray(obj)[ki, oi])
        wall = float(low_t.wall_penalty(low_t.theta0))
        want = (np.log(soft) - np.log(target)) ** 2 + wall
        assert loss_t >= 0.0
        assert loss_t == pytest.approx(want, rel=1e-6)


# ---------------------------------------------------------------------------
# problem: schema round-trip and strictness
# ---------------------------------------------------------------------------


def test_problem_document_round_trip(isocap_problem):
    prob = isocap_problem
    back = inverse.InverseProblem.from_json(prob.to_json())
    assert back == prob
    assert prob.to_doc()["schema"] == inverse.SCHEMA


def test_problem_rejects_unknown_fields(isocap_problem):
    doc = isocap_problem.to_doc()
    doc["unknown_knob"] = 1
    with pytest.raises(ValueError, match="unknown_knob"):
        inverse.InverseProblem.from_json(doc)
    with pytest.raises(ValueError, match="schema"):
        inverse.InverseProblem.from_json({"schema": "bogus"})


def test_problem_validates_fields(isocap_problem):
    import dataclasses
    with pytest.raises(ValueError, match="objective"):
        dataclasses.replace(isocap_problem, objective="power")
    with pytest.raises(ValueError, match="area_budget"):
        dataclasses.replace(isocap_problem, area_budget_mm2="huge")
    with pytest.raises(ValueError, match="temp"):
        dataclasses.replace(isocap_problem, temp_lo=0.0)


def test_shipped_inverse_spec_loads_and_lowers():
    prob = inverse.InverseProblem.load(
        os.path.join(SPECS, "inverse_isocap.json"))
    assert prob.objective == "edp"
    assert prob.area_budget_mm2 == "iso"
    with enable_x64():
        low = relax.lower(prob)
    assert low.area_budget_mm2 > 0.0
    assert {g.key[0] for g in low.groups} == {"stt", "sot"}


# ---------------------------------------------------------------------------
# sens: elasticity tables
# ---------------------------------------------------------------------------


def test_sensitivity_rows_shape_and_finiteness(two_node_lowered):
    low = two_node_lowered
    rows = sensitivity.sensitivity_rows(low.problem, low)
    # 1 platform x 2 scenarios x 4 NVM points x 8 leaves
    assert len(rows) == 1 * 2 * 4 * bounds_mod.N_LEAVES
    for r in rows:
        assert np.isfinite(r["elasticity"])
        assert r["leaf"] in bounds_mod.LEAF_FIELDS
        assert r["mem"] in ("stt", "sot")
    # the headline ranking has one entry per (node, mem)
    top = sensitivity.top_knobs(rows, n=1)
    assert len(top) == 4
    assert all(abs(t["mean_elasticity"]) > 0.0 for t in top)

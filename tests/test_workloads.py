"""Paper Table III validation + traffic model sanity."""

import pytest

from repro.core import traffic
from repro.core.workloads import TABLE3, paper_workloads


@pytest.mark.parametrize("name", list(TABLE3))
def test_table3_macs_params(name):
    w = paper_workloads()[name]
    ref = TABLE3[name]
    assert w.total_macs == pytest.approx(ref["macs"], rel=0.12)
    assert w.total_params == pytest.approx(ref["params"], rel=0.06)
    assert w.fc_layers == ref["fc"]


def test_conv_counts():
    ws = paper_workloads()
    assert ws["alexnet"].conv_layers == 5
    assert ws["googlenet"].conv_layers == 57
    assert ws["vgg16"].conv_layers == 13
    assert ws["squeezenet"].conv_layers == 26
    # paper counts ResNet-18's 17 3x3 convs; we also model the 3
    # downsample 1x1s explicitly
    assert ws["resnet18"].conv_layers == 20


def test_training_has_more_traffic_than_inference():
    w = paper_workloads()["alexnet"]
    inf = traffic.build(w, 4, False)
    tr = traffic.build(w, 4, True)
    assert tr.l2_read_tx > inf.l2_read_tx
    assert tr.l2_write_tx > inf.l2_write_tx
    assert tr.macs_per_batch == pytest.approx(3 * inf.macs_per_batch)


def test_reads_dominate_writes():
    """Paper SSIV-A: read ops dominate write ops in DL workloads."""
    for w in paper_workloads().values():
        st = traffic.build(w, 4, False)
        assert st.read_write_ratio > 1.0


def test_batch_trends_match_fig5():
    """Inference rw-ratio falls with batch; training rises (paper Fig. 5)."""
    w = paper_workloads()["alexnet"]
    inf = [traffic.build(w, b, False).read_write_ratio for b in (1, 16, 64)]
    tr = [traffic.build(w, b, True).read_write_ratio for b in (1, 16, 64)]
    assert inf[0] > inf[-1]
    assert tr[-1] > tr[0]


def test_empty_stream_set_is_zero_traffic():
    """The vectorized fold must degrade like the old generator sums."""
    stats = traffic.TrafficStats("empty", 1, False, (), 0.0)
    assert stats.l2_read_tx == 0.0
    assert stats.l2_write_tx == 0.0
    assert stats.dram_tx(3 * 2**20) == 0.0

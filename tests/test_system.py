"""End-to-end behaviour tests: training loop, fault recovery, serving."""

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokens
from repro.distributed import fault
from repro.launch.specs import schedule_for
from repro.models import lm as lm_mod
from repro.optim import AdamWConfig, adamw_init, make_train_step


def _setup(tmp_path, seq=32, batch=4):
    cfg = configs.get("tinyllama-1.1b", reduced=True)
    model = lm_mod.build(cfg)
    opt = AdamWConfig(schedule=schedule_for(cfg))
    step = jax.jit(make_train_step(model.loss, opt))
    state = adamw_init(model.init(jax.random.PRNGKey(0)))
    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                      global_batch=batch))
    mgr = CheckpointManager(str(tmp_path), keep=3)
    return state, data, step, mgr


def _stepper(step):
    def fn(st, batch):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        return step(st, batch)
    return fn


def test_loss_decreases(tmp_path):
    state, data, step, mgr = _setup(tmp_path)
    state, log = fault.run_resilient(state, data, _stepper(step), mgr,
                                     n_steps=30, checkpoint_every=100)
    assert log[-1]["loss"] < log[0]["loss"]
    assert all(np.isfinite(m["loss"]) for m in log)


def test_fault_recovery_matches_uninterrupted_run(tmp_path):
    """Crash at step 12, restore from step 10, finish — final metrics must
    equal the run without any fault (pure-function data addressing +
    deterministic step)."""
    n = 18
    s1, data, step, mgr1 = _setup(tmp_path / "a")
    s1, log1 = fault.run_resilient(s1, data, _stepper(step), mgr1,
                                   n_steps=n, checkpoint_every=5)
    s2, data2, step2, mgr2 = _setup(tmp_path / "b")
    s2, log2 = fault.run_resilient(s2, data2, _stepper(step2), mgr2,
                                   n_steps=n, checkpoint_every=5,
                                   fault_at=12)
    assert int(s1.step) == int(s2.step) == n
    assert log1[-1]["loss"] == np.float32(log2[-1]["loss"]) or \
        abs(log1[-1]["loss"] - log2[-1]["loss"]) < 1e-5


def test_resume_across_process_restart(tmp_path):
    state, data, step, mgr = _setup(tmp_path)
    state, _ = fault.run_resilient(state, data, _stepper(step), mgr,
                                   n_steps=10, checkpoint_every=5)
    mgr.save(int(state.step), state, blocking=True)
    # "new process": fresh state object, restore latest
    fresh, _, step2, mgr2 = _setup(tmp_path)
    got_step, restored = CheckpointManager(str(tmp_path)).restore_latest(fresh)
    assert got_step == 10
    restored, log = fault.run_resilient(restored, data, _stepper(step2),
                                        mgr2, n_steps=15,
                                        checkpoint_every=100)
    assert int(restored.step) == 15


def test_generation_pipeline():
    from repro.launch import serve
    cfg = configs.get("tinyllama-1.1b", reduced=True)
    model = lm_mod.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    toks = serve.generate(model, params, prompts, max_seq=24, gen=8)
    assert toks.shape == (2, 8)
    assert np.all((np.asarray(toks) >= 0) & (np.asarray(toks) < cfg.vocab))


def test_elastic_reshard_preserves_values():
    from repro.distributed.elastic import reshard_state
    from repro.launch.mesh import make_host_mesh
    cfg = configs.get("tinyllama-1.1b", reduced=True)
    model = lm_mod.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh()
    moved = reshard_state(params, mesh)
    a = jax.tree.leaves(params)[1]
    b = jax.tree.leaves(moved)[1]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Sharding-rule properties (hypothesis) — mesh-shape-agnostic."""

import dataclasses

import jax
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.distributed import sharding  # noqa: E402


@dataclasses.dataclass(frozen=True)
class FakeMesh:
    """Duck-typed mesh: best_fit only touches axis_names and shape."""

    shape: dict
    axis_names: tuple


MESHES = [
    FakeMesh({"data": 16, "model": 16}, ("data", "model")),
    FakeMesh({"pod": 2, "data": 16, "model": 16}, ("pod", "data", "model")),
    FakeMesh({"data": 1, "model": 1}, ("data", "model")),
]

dims = st.lists(st.sampled_from([1, 2, 3, 4, 5, 16, 25, 40, 64, 128, 2048,
                                 32000, 122753]), min_size=1, max_size=4)


@given(dims, st.integers(0, 2))
@settings(max_examples=100, deadline=None)
def test_best_fit_only_assigns_divisible_axes(shape, mesh_i):
    mesh = MESHES[mesh_i]
    prefs = [(i, ax) for i in range(len(shape))
             for ax in mesh.axis_names]
    spec = sharding.best_fit(shape, mesh, prefs)
    used = set()
    for dim, axis in zip(shape, spec):
        if axis is None:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
            assert a not in used, "axis reused across dims"
            used.add(a)
        assert dim % size == 0, f"{dim} not divisible by {size}"


@given(dims)
@settings(max_examples=50, deadline=None)
def test_best_fit_empty_prefs_replicates(shape):
    spec = sharding.best_fit(shape, MESHES[0], [])
    assert spec == P(*([None] * len(shape)))


def test_param_rules_cover_all_archs():
    """Every param leaf of every (reduced) arch gets a legal spec."""
    import repro.configs as configs
    from repro.models import lm as lm_mod
    mesh = MESHES[1]  # 512-device shape, duck-typed
    for arch in configs.all_archs():
        cfg = configs.get(arch, reduced=True)
        model = lm_mod.build(cfg)
        params = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        for path, leaf in flat:
            spec = sharding.param_spec(path, leaf, mesh, fsdp=True)
            for dim, axis in zip(leaf.shape, spec):
                if axis is None:
                    continue
                axes = axis if isinstance(axis, tuple) else (axis,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert dim % size == 0, (arch, path, leaf.shape, spec)


def test_full_configs_shard_on_production_mesh():
    """The published (non-reduced) configs' head/vocab dims: fallbacks must
    engage for the awkward ones (qwen3 40 heads, minicpm 122753 vocab)."""
    import repro.configs as configs
    mesh = MESHES[0]
    qwen = configs.get("qwen3-14b")
    spec = sharding.param_spec(
        (jax.tree_util.GetAttrKey("seg0"), jax.tree_util.DictKey("attn"),
         jax.tree_util.DictKey("wq")),
        jax.ShapeDtypeStruct((qwen.n_layers, qwen.d_model, qwen.n_heads,
                              qwen.head_dim), jax.numpy.float32),
        mesh)
    # 40 heads don't divide 16 -> d_model must carry the model axis
    assert spec[2] is None and spec[1] == "model"

"""Tests for the sharded mega-sweep lowering (core/sweep.py ShardPlan /
split / merge, core/workload_engine chunk evaluation, core/engine
DesignTable.subset, the sweep-mesh path, and the CLI surface).

Families:

  parity     sharded evaluation of every golden spec in specs/ merges to
             the unsharded result within 1e-12, for two chunk sizes and
             a permuted chunk order (the acceptance pin);
  merge      order-invariance, associativity on rectangular groupings,
             disjointness (overlap raises), coverage (missing raises),
             axis/platform/baseline mismatch errors;
  split      exact tiling of the cross product, by_width ordering,
             ShardPlan validation;
  pack       per-chunk pad widths (the padding-blowup fix) and width
             bucketing;
  subset     DesignTable.subset slicing + Algorithm-1 memo reuse;
  mesh       shard_map path on a 1-device sweep mesh (multi-device runs
             live in the CI shard-smoke job under forced host devices);
  cli        --shard flags, mega --quick, serve cells/shard envelope.
"""

import io
import json
import os
import random

import numpy as np
import pytest

from repro import scenarios, sweep_cli
from repro.core import sweep, workload_engine
from repro.core.sweep import (ShardPlan, SymbolicSweepSpec, merge_results,
                              n_cells, run_sharded, split)

SPEC_DIR = os.path.join(os.path.dirname(__file__), "..", "specs")
GOLDEN = ("isocap.json", "dtco.json", "lm_nvm.json", "mixed_cnn_lm.json")
REL = 1e-12

_FIELDS = ("l2_read_tx", "l2_write_tx", "dram_tx", "runtime_s",
           "runtime_nodram_s", "dyn_read_j", "dyn_write_j", "leak_j",
           "leak_nodram_j", "dram_j")


def golden_spec(name: str) -> sweep.SweepSpec:
    with open(os.path.join(SPEC_DIR, name)) as f:
        return SymbolicSweepSpec.from_json(f.read()).resolve()


def max_rel_err(res: sweep.SweepResult, ref: sweep.SweepResult) -> float:
    assert res.scenario_labels == ref.scenario_labels
    assert res.spec.designs == ref.spec.designs
    assert res.designs == ref.designs
    worst = 0.0
    for pi in range(len(ref.spec.platforms)):
        for f in _FIELDS:
            a = getattr(res.tables[pi], f)
            b = getattr(ref.tables[pi], f)
            worst = max(worst, float(np.max(
                np.abs(a - b) / np.maximum(np.abs(b), 1e-300))))
    return worst


# ---------------------------------------------------------------------------
# Acceptance parity: every golden spec, two chunk sizes, permuted order
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", GOLDEN)
@pytest.mark.parametrize("plan", [
    ShardPlan(scenario_chunk=3),
    ShardPlan(scenario_chunk=4, design_chunk=2, by_width=True),
])
def test_golden_sharded_parity(name, plan):
    spec = golden_spec(name)
    assert max_rel_err(run_sharded(spec, plan), sweep.run(spec)) <= REL


@pytest.mark.parametrize("name", GOLDEN)
def test_golden_permuted_chunk_order(name):
    spec = golden_spec(name)
    parts = list(sweep.iter_shards(
        spec, ShardPlan(scenario_chunk=3, design_chunk=2)))
    random.Random(name).shuffle(parts)
    assert max_rel_err(merge_results(iter(parts), spec=spec),
                       sweep.run(spec)) <= REL


# ---------------------------------------------------------------------------
# merge: order-invariance, associativity, disjointness, coverage
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dtco_parts():
    spec = golden_spec("dtco.json")
    return spec, list(sweep.iter_shards(
        spec, ShardPlan(scenario_chunk=4, design_chunk=5)))


def test_merge_without_spec_is_order_invariant(dtco_parts):
    spec, parts = dtco_parts
    ref = merge_results(iter(parts))
    for seed in range(3):
        shuffled = parts[:]
        random.Random(seed).shuffle(shuffled)
        res = merge_results(iter(shuffled))
        assert res.spec == ref.spec  # canonical axes, independent of order
        assert max_rel_err(res, ref) == 0.0


def test_merge_associativity_rectangular(dtco_parts):
    """Merging rectangular sub-groups first, then the groups, equals the
    flat merge: merge is associative on groupings whose intermediates
    tile rectangles (split()'s row groups are such a grouping)."""
    spec, parts = dtco_parts
    flat = merge_results(iter(parts), spec=spec)
    # group by scenario block: each group is one full design row strip
    by_row = {}
    for p in parts:
        by_row.setdefault(p.spec.name.split("#")[1].split(".")[0],
                          []).append(p)
    strips = [merge_results(iter(g)) for g in by_row.values()]
    nested = merge_results(iter(strips), spec=spec)
    assert max_rel_err(nested, flat) == 0.0


def test_merge_overlap_raises(dtco_parts):
    spec, parts = dtco_parts
    with pytest.raises(ValueError, match="overlap"):
        merge_results(iter(parts + parts[:1]), spec=spec)


def test_merge_missing_raises(dtco_parts):
    spec, parts = dtco_parts
    with pytest.raises(ValueError, match="do not tile"):
        merge_results(iter(parts[:-1]), spec=spec)


def test_merge_foreign_axis_raises(dtco_parts):
    spec, parts = dtco_parts
    other = golden_spec("lm_nvm.json")
    alien = next(sweep.iter_shards(other, ShardPlan(scenario_chunk=4)))
    with pytest.raises(ValueError,
                       match="outside the merge target|platforms differ"):
        merge_results(iter(parts[:-1] + [alien]), spec=spec)


def test_merge_empty_raises():
    with pytest.raises(ValueError, match="at least one"):
        merge_results(iter(()))


# ---------------------------------------------------------------------------
# split / ShardPlan
# ---------------------------------------------------------------------------


def test_split_tiles_exactly():
    spec = golden_spec("dtco.json")
    for plan in (ShardPlan(scenario_chunk=3, design_chunk=5),
                 ShardPlan(design_chunk=7),
                 ShardPlan(scenario_chunk=1, design_chunk=1)):
        subs = split(spec, plan)
        cells = [( (s.workload, s.batch, s.training), d)
                 for sub in subs
                 for s in sub.scenarios for d in sub.designs]
        assert len(cells) == len(set(cells)) \
            == len(spec.scenarios) * len(spec.designs)
        assert sum(n_cells(sub) for sub in subs) == n_cells(spec)


def test_split_by_width_orders_wide_first():
    spec = golden_spec("mixed_cnn_lm.json")  # CNN (wide) + LM (6 streams)
    subs = split(spec, ShardPlan(scenario_chunk=2, by_width=True))
    widths = [max(len(s.streams) for s in sub.scenarios) for sub in subs]
    assert widths == sorted(widths, reverse=True)


def test_shardplan_validates():
    with pytest.raises(ValueError, match="scenario_chunk"):
        ShardPlan(scenario_chunk=0)
    with pytest.raises(ValueError, match="devices"):
        ShardPlan(devices=-1)


# ---------------------------------------------------------------------------
# pack width (the padding-blowup fix)
# ---------------------------------------------------------------------------


def test_pad_width_buckets():
    assert workload_engine.pad_width(1) == 8
    assert workload_engine.pad_width(8) == 8
    assert workload_engine.pad_width(9) == 16
    assert workload_engine.pad_width(645) == 1024
    with pytest.raises(ValueError):
        workload_engine.pad_width(0)


def test_pack_per_chunk_width():
    lm = scenarios.lm_scenarios(archs=scenarios.configs.all_archs()[:2],
                                shapes=("train_4k",))
    k = max(len(s.streams) for s in lm)
    assert workload_engine.pack(lm).bytes_total.shape[1] == k
    bucketed = workload_engine.pack(lm, width=workload_engine.pad_width(k))
    assert bucketed.bytes_total.shape[1] <= 16  # LM chunks stay narrow
    with pytest.raises(ValueError):
        workload_engine.pack(lm, width=2)


def test_chunked_width_matches_global(dtco_parts):
    """Padding is mathematically inert: a chunk evaluated at its own
    width equals the same rows of the globally-packed fold (within the
    reduction-reassociation pin)."""
    spec, _ = dtco_parts
    ref = sweep.run(spec)
    sub = split(spec, ShardPlan(scenario_chunk=2))[0]
    tabs = workload_engine.evaluate_chunk(
        sub.scenarios, ref.designs, sub.platforms)
    rows = [ref.scenario_labels.index(k) for k in tabs[0].scenarios]
    a, b = tabs[0].dram_tx, ref.tables[0].dram_tx[rows]
    assert float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-300))) \
        <= REL


# ---------------------------------------------------------------------------
# DesignTable.subset
# ---------------------------------------------------------------------------


def test_design_table_subset_slices_and_memoizes():
    spec = golden_spec("dtco.json")
    table, designs = sweep.lower_designs(spec.designs)
    pts = spec.designs[:4]
    sub = table.subset(
        mems=tuple(dict.fromkeys(p.mem for p in pts)),
        capacities_bytes=tuple(dict.fromkeys(p.capacity_bytes
                                             for p in pts)),
        nodes=tuple(dict.fromkeys(p.node for p in pts)))
    for p, d in zip(spec.designs, designs):
        if p not in pts:
            continue
        # tuned reads agree with the parent table's (memo carried over)
        assert sub.tuned(p.mem, p.capacity_bytes, node=p.node) == \
            table.tuned(p.mem, p.capacity_bytes, node=p.node)
    with pytest.raises(ValueError, match="subset axis"):
        table.subset(mems=("pcm",))


# ---------------------------------------------------------------------------
# mesh path (1 device here; multi-device in the CI shard-smoke job)
# ---------------------------------------------------------------------------


def test_sharded_mesh_single_device_parity():
    spec = golden_spec("isocap.json")
    res = run_sharded(spec, ShardPlan(scenario_chunk=2, design_chunk=3,
                                      devices=1))
    assert max_rel_err(res, sweep.run(spec)) <= REL


def test_sweep_mesh_bounds():
    from repro.distributed.sharding import sweep_mesh
    import jax
    assert sweep_mesh(1).devices.size == 1
    with pytest.raises(ValueError, match="devices"):
        sweep_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# mega spec + CLI
# ---------------------------------------------------------------------------


def test_mega_spec_axes():
    spec = scenarios.mega_spec()
    assert n_cells(spec) >= 100_000
    kinds = {("/" in s.workload) for s in spec.scenarios}
    assert kinds == {True, False}  # heterogeneous: CNN + LM
    assert len({p.node for p in spec.designs}) >= 2
    quick = scenarios.mega_spec(quick=True)
    assert n_cells(quick) < 2_000


def test_cli_run_sharded_matches_unsharded(tmp_path, capsys):
    plain, sharded = tmp_path / "a.csv", tmp_path / "b.csv"
    path = os.path.join(SPEC_DIR, "isocap.json")
    sweep_cli.main(["run", path, "--csv", str(plain)])
    sweep_cli.main(["run", path, "--csv", str(sharded),
                    "--shard", "3", "--by-width"])
    a_lines = plain.read_text().splitlines()
    b_lines = sharded.read_text().splitlines()
    assert a_lines[0] == b_lines[0] and len(a_lines) == len(b_lines)
    for a, b in zip(a_lines[1:], b_lines[1:]):
        for x, y in zip(a.split(","), b.split(",")):
            try:
                fx, fy = float(x), float(y)
            except ValueError:
                assert x == y  # label columns are exact
            else:
                # numeric columns sit within the sharded 1e-12 pin (pad
                # widths differ, so the last ulps of reductions may move)
                assert fy == pytest.approx(fx, rel=REL)


def test_cli_mega_quick(capsys):
    sweep_cli.main(["mega", "--quick", "--shard", "10",
                    "--design-chunk", "6", "--summary"])
    out = capsys.readouterr()
    assert "mega-quick" in out.err and "cells/s" in out.err
    assert json.loads(out.out)  # summary JSON on stdout


def test_serve_reports_cells_and_shard():
    with open(os.path.join(SPEC_DIR, "isocap.json")) as f:
        doc = json.load(f)
    req = {"spec": doc, "want": ["summary"],
           "shard": {"scenario_chunk": 4, "by_width": True}}
    out = io.StringIO()
    served = sweep_cli.serve(
        io.StringIO(json.dumps(req) + "\n" + json.dumps(doc) + "\n"), out)
    assert served == 2
    lines = [json.loads(x) for x in out.getvalue().splitlines()]
    for resp in lines:
        assert resp["ok"] and resp["cells"] == 30
        assert resp["elapsed_ms"] > 0
    bad = sweep_cli.answer(json.dumps(
        {"spec": doc, "shard": {"bogus": 1}}))
    assert not bad["ok"] and "shard" in bad["error"]

"""Scenario registry — one symbolic namespace for every workload the
pipeline can fold.

The paper's CNN workloads enter the pipeline through the traffic model
(``workload_engine.stats_for``); this module is the same entry point for
the assigned LM architectures: every ``repro.configs`` architecture x
{train_4k, prefill_32k, decode_32k, long_500k} shape becomes a packed
:class:`~repro.core.traffic.TrafficStats` built from the analytic byte
accounting the roofline uses (``launch/flops.py``), so the whole LM study
runs as one batched [arch-shape] x [mem, capacity] x [platform] fold on
the workload engine.

Both scenario kinds live under one namespace, resolved by :func:`resolve`
(the symbolic SweepSpec v2 scenario axis, core/sweep.py):

    cnn/<workload>/<stage>@b<batch>   e.g. "cnn/resnet18/train@b64"
    lm/<arch>/<shape>[@b<batch>]      e.g. "lm/qwen3-14b/decode_32k@b8"

The LM ``@b<n>`` suffix overrides the shape's default global batch
(``configs.base.SHAPES``), so serving-fleet batch mixes sweep as
first-class scenario cells; the bare name keeps the registered default
batch (the historical LM-study rows are unchanged).

``name_of`` is the inverse (used to serialize concrete specs), and a
heterogeneous spec may mix both kinds on one scenario axis — they fold in
a single batched evaluation.

``long_500k`` (524k-token decode) is only meaningful for sub-quadratic
architectures (SSM / hybrid / linear attention); ``lm_supported`` encodes
that guard and ``lm_scenarios`` applies it, so quadratic-attention archs
simply have no row for that shape.
"""

from __future__ import annotations

import dataclasses
import functools
from collections.abc import Sequence

import repro.configs as configs
from repro.configs.base import SHAPES
from repro.core import sweep, workload_engine, workloads
from repro.core.tech import Platform, TechNode, TECH_16NM, TPU_V5E
from repro.core.traffic import INF, AccessStream, TrafficStats
from repro.launch import flops as flops_mod

# The LM study's shape axis, in row order.  long_500k rows exist only for
# sub-quadratic architectures (see lm_supported).
LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
LM_CAPACITY_MB = 48  # TPU-class last-level on-chip buffer (VMEM regime)
# Registered batch overrides of the LM namespace (``@b<n>``) — the
# serving-fleet batch mix axis exposed through names()/the sweep service.
LM_BATCHES = (1, 8, 32)


@functools.lru_cache(maxsize=None)
def lm_traffic(arch: str, shape_name: str,
               batch: int | None = None) -> TrafficStats:
    """AccessStreams of one step of an (arch x shape) cell, from the same
    analytic model the roofline uses.  Memoized: scenarios are shared
    across sweeps the same way ``workload_engine.stats_for`` shares the
    paper workloads.  ``batch`` overrides the shape's default global
    batch; the scenario's workload key then carries an ``@b<n>`` suffix
    so ``name_of`` stays the inverse of ``resolve`` and the cell never
    collides with the default-batch one on a scenario axis."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    key = f"{arch}/{shape_name}"
    if batch is not None:
        if not isinstance(batch, int) or batch < 1:
            raise ValueError(f"LM batch override must be a positive int, "
                             f"got {batch!r}")
        shape = dataclasses.replace(shape, global_batch=batch)
        key += f"@b{batch}"
    acct = flops_mod.account(cfg, shape)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    d = cfg.d_model
    streams = [
        AccessStream("weights", acct.param_bytes, False, INF),
        AccessStream("activations.r",
                     12.0 * tokens * d * 2.0, False, 4 * tokens * d // 64),
        AccessStream("activations.w",
                     6.0 * tokens * d * 2.0, True, 4 * tokens * d // 64),
        AccessStream("kv.r", acct.kv_read_bytes, False, INF),
        AccessStream("kv.w", acct.kv_write_bytes, True, INF),
        AccessStream("logits", tokens * cfg.vocab * 4.0, True, INF),
    ]
    if shape.kind == "train":
        streams += [
            AccessStream("grads.w", acct.param_bytes, True, INF),
            AccessStream("opt.r", 3.0 * acct.param_bytes, False, INF),
            AccessStream("opt.w", 2.0 * acct.param_bytes, True, INF),
        ]
    # KV-less cells (e.g. training) must not emit zero-byte streams: they
    # would pollute the packed fold with degenerate entries
    streams = [s for s in streams if s.bytes_total > 0]
    return TrafficStats(key, shape.global_batch,
                        shape.kind == "train", tuple(streams),
                        macs_per_batch=acct.flops / 2.0)


def lm_supported(arch: str, shape_name: str) -> bool:
    """Whether an (arch, shape) cell exists: long_500k needs a
    sub-quadratic architecture."""
    return shape_name != "long_500k" or configs.get(arch).sub_quadratic


def lm_scenarios(archs: Sequence[str] | None = None,
                 shapes: Sequence[str] = LM_SHAPES,
                 ) -> tuple[TrafficStats, ...]:
    """Scenario axis of the LM study: arch-major over every supported
    (arch x shape) cell."""
    archs = tuple(archs) if archs is not None else configs.all_archs()
    return tuple(lm_traffic(a, s) for a in archs for s in shapes
                 if lm_supported(a, s))


# ---------------------------------------------------------------------------
# The unified symbolic namespace (SweepSpec v2 scenario axis)
# ---------------------------------------------------------------------------

_STAGES = {"train": True, "infer": False}


def resolve(name: str) -> TrafficStats:
    """Resolve one symbolic scenario name to its TrafficStats.

    ``cnn/<workload>/<stage>@b<batch>`` routes through the shared memoized
    ``workload_engine.stats_for`` (the paper-CNN entry point);
    ``lm/<arch>/<shape>`` through :func:`lm_traffic`.  Both are memoized,
    so a resolved spec shares scenario objects — and therefore the
    ``sweep.run`` memo — with the equivalent Python-constructed spec.
    """
    kind, _, rest = name.partition("/")
    if kind == "cnn":
        workload_name, _, stage_spec = rest.partition("/")
        stage, sep, batch_s = stage_spec.partition("@b")
        if stage not in _STAGES or not sep or not batch_s.isdigit():
            raise ValueError(
                f"bad CNN scenario {name!r}: expected "
                "'cnn/<workload>/{train|infer}@b<batch>'")
        return workload_engine.stats_for(workloads.get(workload_name),
                                         int(batch_s), _STAGES[stage])
    if kind == "lm":
        arch, _, shape_spec = rest.partition("/")
        shape, sep, batch_s = shape_spec.partition("@b")
        if sep and (not batch_s.isdigit() or int(batch_s) < 1):
            raise ValueError(f"bad LM scenario {name!r}: expected "
                             "'lm/<arch>/<shape>[@b<batch>]' with a "
                             "positive batch")
        if shape not in SHAPES:
            raise ValueError(f"bad LM scenario {name!r}: unknown shape "
                             f"{shape!r}; available: {sorted(SHAPES)}")
        if arch not in configs.all_archs():
            raise ValueError(f"bad LM scenario {name!r}: unknown arch "
                             f"{arch!r}; available: {configs.all_archs()}")
        if not lm_supported(arch, shape):
            raise ValueError(f"unsupported LM scenario {name!r}: "
                             f"{shape} needs a sub-quadratic architecture")
        return lm_traffic(arch, shape, int(batch_s) if sep else None)
    raise ValueError(f"unknown scenario namespace in {name!r}: expected "
                     "'cnn/...' or 'lm/...'")


def name_of(stats: TrafficStats) -> str:
    """Inverse of :func:`resolve` — the symbolic name of a registry-built
    scenario (LM cells carry their 'arch/shape' key as the workload)."""
    if "/" in stats.workload:
        return f"lm/{stats.workload}"
    stage = "train" if stats.training else "infer"
    return f"cnn/{stats.workload}/{stage}@b{stats.batch}"


def names(cnn_stages: Sequence[tuple[bool, int]] = ((False, 4), (True, 64)),
          lm_batches: Sequence[int] = LM_BATCHES) -> tuple[str, ...]:
    """Every scenario name the registry resolves, CNNs at the given
    (training, batch) stages and LM cells at the default batch plus each
    registered ``@b<n>`` override (both namespaces are batch-parametric,
    so representative batches only are enumerated)."""
    cnn = tuple(f"cnn/{w}/{'train' if t else 'infer'}@b{b}"
                for w in workloads.registry() for t, b in cnn_stages)
    lm = tuple(f"lm/{a}/{s}{suffix}"
               for a in configs.all_archs() for s in LM_SHAPES
               if lm_supported(a, s)
               for suffix in ("",) + tuple(f"@b{b}" for b in lm_batches))
    return cnn + lm


# The mega-sweep's CNN batch axis: powers of two through the paper's
# largest studied batch regime.  15 values x 2 stages x 5 workloads = 150
# CNN scenarios; with the 32 LM cells and the 4-node x 24-capacity x 3-mem
# design grid x 2 platforms this crosses 1e5 cells.
MEGA_BATCHES = tuple(2 ** i for i in range(15))          # 1 .. 16384
MEGA_CAPACITIES_MB = (0.5, 1, 1.5, 2, 2.5, 3, 4, 5, 6, 8, 10, 12, 16, 20,
                      24, 28, 32, 40, 48, 56, 64, 72, 80, 96)


def mega_spec(quick: bool = False) -> sweep.SweepSpec:
    """The full DTCO cross product as one spec: every CNN workload x stage
    x batch, every supported LM (arch x shape) cell, x every (node x
    capacity x memory) design point x both platforms — the 1e5-cell space
    the sharded lowering (``sweep.ShardPlan``) exists for.  ``quick``
    shrinks every axis to a CI-smoke size (a few hundred cells) with the
    same heterogeneous shape."""
    from repro.core.tech import NODES, PLATFORMS
    batches = (4, 64) if quick else MEGA_BATCHES
    caps = (1.0, 3.0) if quick else MEGA_CAPACITIES_MB
    nodes = tuple(NODES.values())[:2 if quick else None]
    cnn = tuple(workload_engine.stats_for(w, b, t)
                for w in workloads.registry().values()
                for t in (False, True) for b in batches)
    lm = lm_scenarios(shapes=("train_4k", "decode_32k") if quick
                      else LM_SHAPES)
    return sweep.SweepSpec(
        name="mega-quick" if quick else "mega",
        scenarios=cnn + lm,
        designs=sweep.design_grid(sweep.MEMS, caps, nodes=nodes),
        platforms=tuple(PLATFORMS.values()))


def lm_sweep_spec(capacity_mb: float = LM_CAPACITY_MB,
                  mems: Sequence[str] = sweep.MEMS,
                  platforms: Sequence[Platform] = (TPU_V5E,),
                  archs: Sequence[str] | None = None,
                  shapes: Sequence[str] = LM_SHAPES,
                  nodes: TechNode | Sequence[TechNode] = (TECH_16NM,),
                  name: str = "lm-nvm") -> sweep.SweepSpec:
    """The LM study as one declarative sweep: every supported (arch x
    shape) cell x every (node x memory) design at the TPU-class buffer
    capacity x the requested platforms.  ``nodes`` is the cross-node DTCO
    entry point: several nodes batch through the same single circuit-call
    + single fold-call pipeline, each normalized to its own-node SRAM."""
    return sweep.SweepSpec(
        name=name,
        scenarios=lm_scenarios(archs, shapes),
        designs=sweep.design_grid(mems, (capacity_mb,), nodes=nodes),
        platforms=tuple(platforms))

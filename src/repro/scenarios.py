"""Scenario registry — LM (arch x shape) cells as first-class sweep
scenarios.

The paper's CNN workloads enter the pipeline through the traffic model
(``workload_engine.stats_for``); this module is the same entry point for
the assigned LM architectures: every ``repro.configs`` architecture x
{train_4k, decode_32k, long_500k} shape becomes a packed
:class:`~repro.core.traffic.TrafficStats` built from the analytic byte
accounting the roofline uses (``launch/flops.py``), so the whole LM study
runs as one batched [arch-shape] x [mem, capacity] x [platform] fold on
the workload engine.

``long_500k`` (524k-token decode) is only meaningful for sub-quadratic
architectures (SSM / hybrid / linear attention); ``lm_supported`` encodes
that guard and ``lm_scenarios`` applies it, so quadratic-attention archs
simply have no row for that shape.
"""

from __future__ import annotations

import functools
from collections.abc import Sequence

import repro.configs as configs
from repro.configs.base import SHAPES
from repro.core import sweep
from repro.core.tech import Platform, TechNode, TECH_16NM, TPU_V5E
from repro.core.traffic import INF, AccessStream, TrafficStats
from repro.launch import flops as flops_mod

# The LM study's shape axis, in row order.  long_500k rows exist only for
# sub-quadratic architectures (see lm_supported).
LM_SHAPES = ("train_4k", "decode_32k", "long_500k")
LM_CAPACITY_MB = 48  # TPU-class last-level on-chip buffer (VMEM regime)


@functools.lru_cache(maxsize=None)
def lm_traffic(arch: str, shape_name: str) -> TrafficStats:
    """AccessStreams of one step of an (arch x shape) cell, from the same
    analytic model the roofline uses.  Memoized: scenarios are shared
    across sweeps the same way ``workload_engine.stats_for`` shares the
    paper workloads."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    acct = flops_mod.account(cfg, shape)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    d = cfg.d_model
    streams = [
        AccessStream("weights", acct.param_bytes, False, INF),
        AccessStream("activations.r",
                     12.0 * tokens * d * 2.0, False, 4 * tokens * d // 64),
        AccessStream("activations.w",
                     6.0 * tokens * d * 2.0, True, 4 * tokens * d // 64),
        AccessStream("kv.r", acct.kv_read_bytes, False, INF),
        AccessStream("kv.w", acct.kv_write_bytes, True, INF),
        AccessStream("logits", tokens * cfg.vocab * 4.0, True, INF),
    ]
    if shape.kind == "train":
        streams += [
            AccessStream("grads.w", acct.param_bytes, True, INF),
            AccessStream("opt.r", 3.0 * acct.param_bytes, False, INF),
            AccessStream("opt.w", 2.0 * acct.param_bytes, True, INF),
        ]
    # KV-less cells (e.g. training) must not emit zero-byte streams: they
    # would pollute the packed fold with degenerate entries
    streams = [s for s in streams if s.bytes_total > 0]
    return TrafficStats(f"{arch}/{shape_name}", shape.global_batch,
                        shape.kind == "train", tuple(streams),
                        macs_per_batch=acct.flops / 2.0)


def lm_supported(arch: str, shape_name: str) -> bool:
    """Whether an (arch, shape) cell exists: long_500k needs a
    sub-quadratic architecture."""
    return shape_name != "long_500k" or configs.get(arch).sub_quadratic


def lm_scenarios(archs: Sequence[str] | None = None,
                 shapes: Sequence[str] = LM_SHAPES,
                 ) -> tuple[TrafficStats, ...]:
    """Scenario axis of the LM study: arch-major over every supported
    (arch x shape) cell."""
    archs = tuple(archs) if archs is not None else configs.all_archs()
    return tuple(lm_traffic(a, s) for a in archs for s in shapes
                 if lm_supported(a, s))


def lm_sweep_spec(capacity_mb: float = LM_CAPACITY_MB,
                  mems: Sequence[str] = sweep.MEMS,
                  platforms: Sequence[Platform] = (TPU_V5E,),
                  archs: Sequence[str] | None = None,
                  shapes: Sequence[str] = LM_SHAPES,
                  nodes: TechNode | Sequence[TechNode] = (TECH_16NM,),
                  name: str = "lm-nvm") -> sweep.SweepSpec:
    """The LM study as one declarative sweep: every supported (arch x
    shape) cell x every (node x memory) design at the TPU-class buffer
    capacity x the requested platforms.  ``nodes`` is the cross-node DTCO
    entry point: several nodes batch through the same single circuit-call
    + single fold-call pipeline, each normalized to its own-node SRAM."""
    return sweep.SweepSpec(
        name=name,
        scenarios=lm_scenarios(archs, shapes),
        designs=sweep.design_grid(mems, (capacity_mb,), nodes=nodes),
        platforms=tuple(platforms))

"""Public sweep API + the ``python -m repro.sweep`` service entry point.

Re-exports the declarative pipeline (repro.core.sweep) and the symbolic
SweepSpec v2 document layer so consumers address one namespace:

    from repro import sweep
    result = sweep.load_spec("spec.json").run()

``python -m repro.sweep run|show|serve`` dispatches to repro.sweep_cli.
"""

from repro.core.sweep import (  # noqa: F401
    SCHEMA,
    DesignCorners,
    DesignGrid,
    DesignPoint,
    ShardPlan,
    SweepResult,
    SweepSpec,
    SweepView,
    SymbolicSweepSpec,
    design_corners,
    design_grid,
    design_name,
    group_label,
    iter_shards,
    load_spec,
    merge_results,
    n_cells,
    parse_design,
    run,
    run_sharded,
    split,
    workload_scenarios,
)

__all__ = [
    "SCHEMA", "DesignCorners", "DesignGrid", "DesignPoint", "ShardPlan",
    "SweepResult", "SweepSpec", "SweepView", "SymbolicSweepSpec",
    "design_corners", "design_grid", "design_name", "group_label",
    "iter_shards", "load_spec", "merge_results", "n_cells", "parse_design",
    "run", "run_sharded", "split", "workload_scenarios",
]

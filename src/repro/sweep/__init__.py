"""Public sweep API + the ``python -m repro.sweep`` service entry point.

Re-exports the declarative pipeline (repro.core.sweep) and the symbolic
SweepSpec v2 document layer so consumers address one namespace:

    from repro import sweep
    result = sweep.load_spec("spec.json").run()

``python -m repro.sweep run|show|serve`` dispatches to repro.sweep_cli;
the concurrent service layer (transports, coalescing, cache, warmup)
lives in ``repro.sweep.service`` with a stdlib client in
``repro.sweep.client``.
"""

from repro.core.sweep import (  # noqa: F401
    SCHEMA,
    DesignCorners,
    DesignGrid,
    DesignPoint,
    ShardPlan,
    SweepResult,
    SweepSpec,
    SweepView,
    SymbolicSweepSpec,
    design_corners,
    design_grid,
    design_name,
    group_label,
    iter_shards,
    load_spec,
    lower_designs,
    merge_results,
    n_cells,
    parse_design,
    run,
    run_sharded,
    spec_union,
    split,
    workload_scenarios,
)
from repro.sweep.service import (  # noqa: F401
    Coalescer,
    ResultCache,
    SweepHTTPServer,
    SweepService,
    SweepUnixServer,
    enable_compilation_cache,
    evaluate_spec,
    serve_stdio,
    spec_key,
)

__all__ = [
    "SCHEMA", "Coalescer", "DesignCorners", "DesignGrid", "DesignPoint",
    "ResultCache", "ShardPlan", "SweepHTTPServer", "SweepResult",
    "SweepService", "SweepSpec", "SweepUnixServer", "SweepView",
    "SymbolicSweepSpec", "design_corners", "design_grid", "design_name",
    "enable_compilation_cache", "evaluate_spec", "group_label",
    "iter_shards", "load_spec", "lower_designs", "merge_results",
    "n_cells", "parse_design", "run", "run_sharded", "serve_stdio",
    "spec_key", "spec_union", "split", "workload_scenarios",
]

"""Concurrent sweep service — one request handler behind HTTP,
unix-socket, and stdin-JSONL transports, with request coalescing, a
result cache, and cold-start-killing warmup.

The JSONL stdin loop (``python -m repro.sweep serve``) was a
single-threaded facade over the memoized sweep pipeline; this module is
the production form the ROADMAP's "heavy traffic" north star asks for:

* **Transports** (stdlib only): :class:`SweepHTTPServer` (threaded; POST
  a request document to ``/``, ``GET /stats`` and ``GET /healthz``),
  :class:`SweepUnixServer` (threaded unix socket speaking the same JSONL
  protocol as stdin), and :func:`serve_stdio` (the original loop, now a
  thin adapter over the same :meth:`SweepService.handle`).

* **Request coalescing** (:class:`Coalescer`): concurrent in-flight
  specs that arrive within a small batching window and declare the same
  platform axis are merged into one superset spec
  (``core.sweep.spec_union``), evaluated **once** through the bucketed
  fold (``workload_engine.evaluate_bucketed``), and sliced back into
  per-request results (``SweepResult.subset``) — the batched-evaluation
  economics of the sweep engine applied across requests.  *Identical*
  in-flight requests (same canonical spec document) collapse further:
  they share one queue entry, skipping even the resolve, so a thundering
  herd of clients asking the same golden question costs one evaluation.
  Per-request values match an individual ``run()`` at <= 1e-12 (padding
  reassociates reductions, so bit-identity is not claimed).

* **Result cache**: bounded, keyed on the canonical serialized symbolic
  spec (``json.dumps(sym.to_doc(), sort_keys=True)``), with hit/miss
  counters.  Sharded (``"shard"``-envelope) requests bypass both the
  cache and the coalescer, mirroring ``run()``'s no-memo policy for
  mega-results.

* **Backpressure**: a bounded admission gate (``max_pending``
  concurrent evaluations; cache hits and ops are never refused) and a
  request-document size limit (``max_body_bytes``; the HTTP transport
  refuses oversize bodies before reading them).  Refusals answer with
  ``{"ok": false, "status": 413 | 429, "error": ...}`` — HTTP maps the
  status onto the response code, JSONL clients read it from the
  document — and are counted in ``stats()["limits"]``.

* **Warmup** (:meth:`SweepService.warmup`): resolves the given specs,
  builds their real design tables through the capacity-bucketed circuit
  path (priming bitcell characterization, calibration, Algorithm-1
  tunings, and the PPA-kernel traces), and compiles the fold kernel at
  their bucketed shapes — plus an optional spec-independent shape grid
  (``workload_engine.warmup`` / ``engine.warmup``) and JAX
  persistent-compilation-cache wiring (:func:`enable_compilation_cache`)
  so compiles survive process restarts.  A warmed service answers its
  first real request at warm cost (~ms) instead of the ~1.8 s cold
  start (BENCH_serve.json pins the ratio).

Graceful shutdown: transports wrap each request in
:meth:`SweepService.track`, so :meth:`SweepService.close` can drain
in-flight requests (including any sitting in the coalescing window)
before stopping the worker — SIGTERM/SIGINT never drop a response that
was accepted.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import socketserver
import sys
import threading
import time
from collections import OrderedDict, deque
from collections.abc import Mapping, Sequence
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro.core import engine, workload_engine
from repro.core.sweep import (
    ShardPlan,
    SweepResult,
    SweepSpec,
    SymbolicSweepSpec,
    lower_designs,
    n_cells,
    run_sharded,
    spec_union,
)

WANTS = ("rows", "summary", "pareto", "plateaus")
SHARD_KEYS = ("scenario_chunk", "design_chunk", "devices", "by_width")
OPS = ("ping", "stats")


class RequestTooLarge(ValueError):
    """Request document exceeds ``max_body_bytes`` (HTTP 413)."""

    http_status = 413


class ServiceOverloaded(RuntimeError):
    """Admission refused: ``max_pending`` evaluations already in flight
    (HTTP 429).  Cache hits and ops are never refused — only work that
    would start a new evaluation."""

    http_status = 429


# ---------------------------------------------------------------------------
# Request documents
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Parsed:
    sym: SymbolicSweepSpec
    want: tuple[str, ...]
    include_dram: bool
    plan: ShardPlan | None


def _parse(req: Mapping) -> _Parsed:
    """One serve-mode request document (bare spec or envelope) -> the
    validated pieces.  The envelope form::

        {"spec": {...}, "want": ["rows", ...], "include_dram": false,
         "shard": {"scenario_chunk": 8, ...}}
    """
    envelope = isinstance(req, Mapping) and "spec" in req
    doc = req["spec"] if envelope else req
    want = tuple(req.get("want", ("summary",))) if envelope else ("summary",)
    unknown = set(want) - set(WANTS)
    if unknown:
        raise ValueError(f"unknown want items {sorted(unknown)}; "
                         f"available: {list(WANTS)}")
    include_dram = bool(req.get("include_dram", False)) if envelope else False
    plan = None
    if envelope and req.get("shard") is not None:
        shard = dict(req["shard"])
        unknown = set(shard) - set(SHARD_KEYS)
        if unknown:
            raise ValueError(f"unknown shard keys {sorted(unknown)}; "
                             f"available: {list(SHARD_KEYS)}")
        plan = ShardPlan(**shard)
    return _Parsed(SymbolicSweepSpec.from_json(doc), want, include_dram,
                   plan)


def _axes(spec: SweepSpec) -> dict:
    return {"platforms": len(spec.platforms),
            "scenarios": len(spec.scenarios),
            "designs": len(spec.designs)}


def _views(result: SweepResult, want: Sequence[str],
           include_dram: bool) -> dict:
    out: dict = {}
    if "rows" in want:
        out["rows"] = result.rows(include_dram=include_dram)
    if "summary" in want:
        out["summary"] = result.summary()
    if "pareto" in want:
        out["pareto"] = result.pareto_front(include_dram=include_dram)
    if "plateaus" in want:
        out["plateaus"] = result.capacity_plateaus()
    return out


def spec_key(sym: SymbolicSweepSpec) -> str:
    """The result-cache key: the canonical serialized symbolic spec."""
    return json.dumps(sym.to_doc(), sort_keys=True)


# ---------------------------------------------------------------------------
# Evaluation path (bucketed shapes end to end)
# ---------------------------------------------------------------------------


def evaluate_spec(spec: SweepSpec) -> SweepResult:
    """The service's one-spec evaluation: the capacity-bucketed circuit
    lowering plus the bucketed fold, so every compile lands on a shape
    ``warmup`` can pre-trace.  Matches ``sweep.run(spec)`` at <= 1e-12;
    the exact (unbucketed) path stays the CLI ``run`` default, whose
    golden CSVs are pinned bit-for-bit."""
    table, designs = lower_designs(spec.designs, pad_caps=True)
    tables = workload_engine.evaluate_bucketed(spec.scenarios, designs,
                                               spec.platforms)
    return SweepResult(spec=spec, design_table=table, designs=designs,
                       tables=tables)


# ---------------------------------------------------------------------------
# Coalescer: the batching window
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class _Pending:
    """One submitted spec awaiting its (exactly-once) result.  Identical
    concurrent requests (same canonical ``key``) share one pending —
    ``claims`` counts the callers waiting on it."""

    spec: SweepSpec
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    result: SweepResult | None = None
    error: BaseException | None = None
    group_size: int = 1
    key: str | None = None
    claims: int = 1

    @property
    def shared(self) -> bool:
        """Did this request share its evaluation with another?"""
        return self.group_size > 1 or self.claims > 1


class Coalescer:
    """Merge compatible in-flight specs into one superset evaluation.

    ``submit`` blocks the calling transport thread until a dedicated
    worker has answered the request.  The worker collects everything that
    arrives within ``window_ms`` of the first pending request (up to
    ``max_batch``), partitions the batch into compatibility groups (the
    ``spec_union`` rule: identical platform axis), evaluates each group's
    union **once**, and slices each member's view back out.  Every
    pending request is delivered exactly once — on success, on a
    per-request slice failure, or on a group evaluation failure — and
    ``close`` refuses new work but drains everything already queued.
    """

    def __init__(self, evaluate=evaluate_spec, window_ms: float = 5.0,
                 max_batch: int = 64):
        self._evaluate = evaluate
        self.window_s = max(0.0, window_ms) / 1e3
        self.max_batch = max(1, max_batch)
        self._cv = threading.Condition()
        self._queue: list[_Pending] = []
        self._keyed: dict[str, _Pending] = {}   # queued, by canonical key
        self._closed = False
        self.batches = 0             # evaluation groups run
        self.coalesced_requests = 0  # requests merged through a union
        self.deduped_requests = 0    # identical in-flight requests shared
        self.max_group = 0
        self._worker = threading.Thread(target=self._loop,
                                        name="sweep-coalescer", daemon=True)
        self._worker.start()

    def join(self, key: str) -> _Pending | None:
        """Attach to an identical queued request (same canonical key)
        without resolving or submitting anything; None if no such request
        is in the window.  The caller waits on the returned pending."""
        with self._cv:
            pending = self._keyed.get(key)
            if pending is not None:
                pending.claims += 1
                self.deduped_requests += 1
        if pending is not None:
            pending.event.wait()
            if pending.error is not None:
                raise pending.error
        return pending

    def submit(self, spec: SweepSpec, key: str | None = None) -> _Pending:
        with self._cv:
            if self._closed:
                raise RuntimeError("coalescer is closed")
            pending = self._keyed.get(key) if key is not None else None
            if pending is None:
                pending = _Pending(spec, key=key)
                self._queue.append(pending)
                if key is not None:
                    self._keyed[key] = pending
                self._cv.notify_all()
            else:
                pending.claims += 1
                self.deduped_requests += 1
        pending.event.wait()
        if pending.error is not None:
            raise pending.error
        return pending

    def close(self) -> None:
        """Refuse new submissions, drain the queue, stop the worker."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
        self._worker.join()

    # -- worker ------------------------------------------------------------

    def _collect(self) -> list[_Pending]:
        with self._cv:
            while not self._queue and not self._closed:
                self._cv.wait()
            if not self._queue:
                return []       # closed and drained
            deadline = time.monotonic() + self.window_s
            while len(self._queue) < self.max_batch and not self._closed:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                self._cv.wait(timeout=left)
            batch = self._queue[:self.max_batch]
            del self._queue[:self.max_batch]
            for p in batch:     # late identical arrivals start a new entry
                if p.key is not None:
                    self._keyed.pop(p.key, None)
            return batch

    def _loop(self) -> None:
        while True:
            batch = self._collect()
            if not batch:
                return
            groups: dict[tuple, list[_Pending]] = {}
            for p in batch:
                groups.setdefault(p.spec.platforms, []).append(p)
            for group in groups.values():
                self._run_group(group)

    def _run_group(self, group: list[_Pending]) -> None:
        with self._cv:  # stats() reads these counters concurrently
            self.batches += 1
            self.max_group = max(self.max_group, len(group))
        try:
            if len(group) == 1:
                group[0].result = self._evaluate(group[0].spec)
            else:
                union = spec_union([p.spec for p in group],
                                   name=f"coalesced[{len(group)}]")
                superset = self._evaluate(union)
                for p in group:
                    try:
                        p.result = superset.subset(p.spec)
                    except BaseException as e:  # noqa: BLE001 — isolate
                        p.error = e
                with self._cv:
                    self.coalesced_requests += len(group)
        except BaseException as e:  # noqa: BLE001 — the worker must live
            for p in group:
                if p.result is None and p.error is None:
                    p.error = e
        finally:
            for p in group:
                p.group_size = len(group)
                p.event.set()


# ---------------------------------------------------------------------------
# Result cache
# ---------------------------------------------------------------------------


class ResultCache:
    """Bounded FIFO result cache keyed on the canonical serialized spec
    (two textually different but equivalent documents hash apart — each
    pays one evaluation, both land in the cache)."""

    def __init__(self, maxsize: int = 256):
        self.maxsize = max(0, maxsize)
        self._entries: OrderedDict[str, SweepResult] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> SweepResult | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.hits += 1
            else:
                self.misses += 1
            return entry

    def put(self, key: str, result: SweepResult) -> None:
        if not self.maxsize:
            return
        with self._lock:
            self._entries[key] = result
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)


# ---------------------------------------------------------------------------
# The service
# ---------------------------------------------------------------------------


def _percentiles(xs: Sequence[float]) -> dict:
    if not xs:
        return {"p50": None, "p95": None}
    return {"p50": float(np.percentile(xs, 50)),
            "p95": float(np.percentile(xs, 95))}


class SweepService:
    """The shared request handler every transport speaks to.

    ``handle`` takes one request document (a JSON string or a mapping)
    and returns one JSON-serializable response document — the same
    contract the stdin JSONL loop always had, now concurrency-safe:
    transport threads call it freely, and spec evaluations funnel through
    the coalescer's single worker (or, with ``coalesce=False``, run
    inline in the calling thread)."""

    def __init__(self, window_ms: float = 5.0, max_batch: int = 64,
                 coalesce: bool = True, cache_size: int = 256,
                 evaluate=evaluate_spec, max_pending: int = 64,
                 max_body_bytes: int = 1 << 20):
        self._evaluate = evaluate
        self.cache = ResultCache(cache_size)
        self.coalescer = Coalescer(evaluate, window_ms, max_batch) \
            if coalesce else None
        self.warmup_info: dict | None = None
        self._closed = False
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._samples: deque[tuple[int, float]] = deque(maxlen=4096)
        self.requests = 0
        self.ok = 0
        self.errors = 0
        # Backpressure limits: evaluations admitted concurrently, and the
        # largest request document a transport will read.
        self.max_pending = max(1, max_pending)
        self.max_body_bytes = max(1, max_body_bytes)
        self._pending = 0
        self.rejected_too_large = 0
        self.rejected_overloaded = 0
        self._inflight = 0
        self._inflight_cv = threading.Condition()

    # -- request handling --------------------------------------------------

    def handle(self, request: Mapping | str) -> dict:
        """One request -> one response document (never raises).  Refused
        requests (oversize document, admission limit) answer with
        ``{"ok": false, "error": ..., "status": 413 | 429}``."""
        t0 = time.perf_counter()
        try:
            if isinstance(request, str) \
                    and len(request) > self.max_body_bytes:
                raise RequestTooLarge(
                    f"request document is {len(request)} bytes "
                    f"(max_body_bytes={self.max_body_bytes})")
            req = json.loads(request) if isinstance(request, str) \
                else request
            if isinstance(req, Mapping) and "op" in req:
                return self._op(req)
            parsed = _parse(req)
            result, source = self._result_for(parsed)
            elapsed_ms = (time.perf_counter() - t0) * 1e3
            resp: dict = {"ok": True, "name": parsed.sym.name,
                          "axes": _axes(result.spec),
                          "cells": n_cells(result.spec),
                          "elapsed_ms": elapsed_ms,
                          "source": source}
            resp.update(_views(result, parsed.want, parsed.include_dram))
            self._record(True, n_cells(result.spec), elapsed_ms)
            return resp
        except Exception as e:  # noqa: BLE001 — the server must survive
            return self._error_response(
                e, (time.perf_counter() - t0) * 1e3)

    def refuse_oversized(self, nbytes: int) -> dict:
        """A transport-level 413 for a body it refused to even read
        (same counting and document shape as the in-handler guard)."""
        return self._error_response(
            RequestTooLarge(f"request body is {nbytes} bytes "
                            f"(max_body_bytes={self.max_body_bytes})"),
            0.0)

    def _error_response(self, e: BaseException, elapsed_ms: float) -> dict:
        with self._lock:
            if isinstance(e, RequestTooLarge):
                self.rejected_too_large += 1
            elif isinstance(e, ServiceOverloaded):
                self.rejected_overloaded += 1
        self._record(False, 0, elapsed_ms)
        resp = {"ok": False, "error": f"{type(e).__name__}: {e}"}
        status = getattr(e, "http_status", None)
        if status is not None:
            resp["status"] = status
        return resp

    @contextlib.contextmanager
    def _admit(self):
        """Admission gate around work that starts a new evaluation
        (cache misses and sharded runs; cache hits and ops bypass it)."""
        with self._lock:
            if self._pending >= self.max_pending:
                raise ServiceOverloaded(
                    f"{self._pending} evaluations already pending "
                    f"(max_pending={self.max_pending})")
            self._pending += 1
        try:
            yield
        finally:
            with self._lock:
                self._pending -= 1

    def _op(self, req: Mapping) -> dict:
        op = req["op"]
        if op == "ping":
            return {"ok": True, "op": "ping"}
        if op == "stats":
            return {"ok": True, "op": "stats", "stats": self.stats()}
        raise ValueError(f"unknown op {op!r}; available: {list(OPS)}")

    def _result_for(self, parsed: _Parsed) -> tuple[SweepResult, str]:
        if parsed.plan is not None:
            # sharded mega-requests stream through merge and bypass both
            # the cache and the coalescer (run()'s no-memo policy: the
            # results are too large to pin) — but not the admission gate:
            # they are the heaviest requests the service takes
            with self._admit():
                return run_sharded(parsed.sym.resolve(),
                                   parsed.plan), "sharded"
        key = spec_key(parsed.sym)
        hit = self.cache.get(key)
        if hit is not None:
            return hit, "cache"
        with self._admit():
            if self.coalescer is not None:
                # identical in-flight request? share it without resolving
                pending = self.coalescer.join(key)
                if pending is None:
                    pending = self.coalescer.submit(parsed.sym.resolve(),
                                                    key=key)
                result = pending.result
                source = "coalesced" if pending.shared else "evaluated"
            else:
                result = self._evaluate(parsed.sym.resolve())
                source = "evaluated"
        self.cache.put(key, result)
        return result, source

    def _record(self, ok: bool, cells: int, elapsed_ms: float) -> None:
        with self._lock:
            self.requests += 1
            if ok:
                self.ok += 1
                self._samples.append((cells, elapsed_ms))
            else:
                self.errors += 1

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        """The ``{"op": "stats"}`` document: counters plus per-request
        cells and elapsed_ms percentiles over the last 4096 requests."""
        with self._lock:
            samples = list(self._samples)
            doc: dict = {
                "uptime_s": time.monotonic() - self._t0,
                "requests": {"total": self.requests, "ok": self.ok,
                             "errors": self.errors},
                "result_cache": {"hits": self.cache.hits,
                                 "misses": self.cache.misses,
                                 "size": len(self.cache),
                                 "maxsize": self.cache.maxsize},
                "limits": {"max_pending": self.max_pending,
                           "max_body_bytes": self.max_body_bytes,
                           "pending": self._pending,
                           "rejected_too_large": self.rejected_too_large,
                           "rejected_overloaded":
                               self.rejected_overloaded},
            }
        c = self.coalescer
        doc["coalesce"] = {
            "enabled": c is not None,
            "batches": c.batches if c else 0,
            "coalesced_requests": c.coalesced_requests if c else 0,
            "deduped_requests": c.deduped_requests if c else 0,
            "max_group": c.max_group if c else 0,
            "window_ms": c.window_s * 1e3 if c else 0.0,
        }
        cells = [n for n, _ in samples]
        lat = [ms for _, ms in samples]
        doc["cells"] = {"total": int(sum(cells)), **_percentiles(cells)}
        doc["elapsed_ms"] = _percentiles(lat)
        if self.warmup_info is not None:
            doc["warmup"] = self.warmup_info
        return doc

    # -- warmup ------------------------------------------------------------

    def warmup(self, specs: Sequence = (), compile_cache_dir=None,
               grid: bool = False) -> dict:
        """Kill the cold start before the first request lands.

        ``specs`` (paths, documents, symbolic or concrete specs) warm the
        exact request shapes: scenario statistics, the capacity-bucketed
        design tables (bitcell + calibration + PPA traces + Algorithm-1
        tunings), and the fold kernel at each spec's bucketed (s, k, d, p)
        shape.  ``grid`` additionally pre-traces the spec-independent
        shape grids (``engine.warmup`` + ``workload_engine.warmup``).
        ``compile_cache_dir`` wires the JAX persistent compilation cache
        first, so the traces this warmup compiles are reused across
        process restarts."""
        t0 = time.perf_counter()
        info: dict = {"specs": [], "grid": bool(grid), "fold_shapes": 0}
        if compile_cache_dir:
            info["compile_cache"] = enable_compilation_cache(
                compile_cache_dir)
            info["compile_cache_dir"] = str(compile_cache_dir)
        if grid:
            info["engine_tables"] = engine.warmup()
            info["fold_shapes"] += workload_engine.warmup()
        shapes = set()
        for item in specs:
            spec = _as_spec(item)
            lower_designs(spec.designs, pad_caps=True)
            shapes.add(workload_engine.fold_shape(
                len(spec.scenarios),
                max(len(s.streams) for s in spec.scenarios),
                len(spec.designs), len(spec.platforms)))
            info["specs"].append(spec.name)
        for shape in sorted(shapes):
            workload_engine.warmup_fold(shape)
        info["fold_shapes"] += len(shapes)
        info["warmup_s"] = time.perf_counter() - t0
        with self._lock:  # stats() snapshots warmup_info concurrently
            self.warmup_info = info
        return info

    # -- lifecycle ---------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    @contextlib.contextmanager
    def track(self):
        """Transports wrap each request *and its response write* in this,
        so ``drain`` waits for delivery, not just computation."""
        with self._inflight_cv:
            self._inflight += 1
        try:
            yield
        finally:
            with self._inflight_cv:
                self._inflight -= 1
                self._inflight_cv.notify_all()

    def drain(self, timeout: float | None = None) -> bool:
        """Wait until no request is in flight (tracked by ``track``)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._inflight_cv:
            while self._inflight:
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    return False
                self._inflight_cv.wait(left)
        return True

    def close(self, timeout: float | None = None) -> None:
        """Graceful shutdown: drain in-flight requests (which flushes the
        coalescing window — queued specs are evaluated and delivered),
        then stop the worker.  Idempotent; ``handle`` after close answers
        with an error document instead of evaluating."""
        if self._closed:
            return
        self.drain(timeout)
        with self._lock:  # handle() checks closed from transport threads
            self._closed = True
        if self.coalescer is not None:
            self.coalescer.close()

    def __enter__(self) -> SweepService:
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _as_spec(item) -> SweepSpec:
    """Warmup-spec coercion: path, JSON document, symbolic, or concrete."""
    if isinstance(item, SweepSpec):
        return item
    if isinstance(item, SymbolicSweepSpec):
        return item.resolve()
    if isinstance(item, str):
        return SymbolicSweepSpec.load(item).resolve()
    if isinstance(item, Mapping):
        return SymbolicSweepSpec.from_json(item).resolve()
    raise TypeError(f"cannot warm up from {type(item).__name__}")


def enable_compilation_cache(path) -> bool:
    """Wire the JAX persistent compilation cache at ``path`` (created if
    missing, thresholds dropped so every fold/PPA trace is persisted).
    Compiled executables then survive process restarts: a service booting
    with the same cache dir skips straight past the XLA compiles that
    dominate the cold start.  Returns False if this jax build lacks the
    knobs (the service still runs, just without cross-process reuse)."""
    import jax
    try:
        os.makedirs(str(path), exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", str(path))
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:  # pragma: no cover — version-dependent knobs
        return False
    return True


# ---------------------------------------------------------------------------
# Transports
# ---------------------------------------------------------------------------


class _HttpHandler(BaseHTTPRequestHandler):
    """POST / (or /sweep) with a request document; GET /stats, /healthz."""

    server_version = "deepnvm-sweep/1"
    protocol_version = "HTTP/1.0"   # close per request: shutdown never
    #                                 waits on idle keep-alive connections

    def _reply(self, code: int, doc: dict) -> None:
        body = (json.dumps(doc) + "\n").encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_POST(self) -> None:  # noqa: N802 — BaseHTTPRequestHandler API
        if self.path not in ("/", "/sweep"):
            self._reply(404, {"ok": False,
                              "error": f"NotFound: POST {self.path}"})
            return
        svc = self.server.service
        with svc.track():
            n = int(self.headers.get("Content-Length") or 0)
            if n > svc.max_body_bytes:
                # refuse before reading: an oversize body never touches
                # the parser or the heap
                resp = svc.refuse_oversized(n)
            else:
                body = self.rfile.read(n).decode("utf-8", "replace")
                resp = svc.handle(body)
            self._reply(200 if resp.get("ok")
                        else int(resp.get("status", 400)), resp)

    def do_GET(self) -> None:  # noqa: N802
        if self.path == "/healthz":
            self._reply(200, {"ok": True})
        elif self.path == "/stats":
            self._reply(200, self.server.service.handle({"op": "stats"}))
        else:
            self._reply(404, {"ok": False,
                              "error": f"NotFound: GET {self.path}"})

    def log_message(self, fmt, *args) -> None:  # stderr stays quiet
        pass


class SweepHTTPServer(ThreadingHTTPServer):
    """Threaded HTTP transport.  Handler threads are daemons and close
    does not join them — graceful shutdown goes through
    ``service.drain()``, which waits for tracked request delivery."""

    daemon_threads = True
    block_on_close = False

    def __init__(self, address: tuple[str, int], service: SweepService):
        super().__init__(address, _HttpHandler)
        self.service = service


class _JsonlHandler(socketserver.StreamRequestHandler):
    """One JSONL request per line in, one response line out — the stdin
    protocol, per connection."""

    def handle(self) -> None:
        for raw in self.rfile:
            line = raw.decode("utf-8", "replace").strip()
            if not line:
                continue
            with self.server.service.track():
                resp = self.server.service.handle(line)
                self.wfile.write((json.dumps(resp) + "\n").encode())
                self.wfile.flush()


if hasattr(socketserver, "ThreadingUnixStreamServer"):
    class SweepUnixServer(socketserver.ThreadingUnixStreamServer):
        """Threaded unix-socket transport speaking line-delimited JSON
        (the stdin protocol over a socket).  A stale socket path is
        unlinked on bind; like the HTTP server, shutdown drains via the
        service."""

        daemon_threads = True
        block_on_close = False

        def __init__(self, path: str, service: SweepService):
            if os.path.exists(path):
                os.unlink(path)
            super().__init__(path, _JsonlHandler)
            self.service = service
else:  # pragma: no cover — platforms without AF_UNIX
    SweepUnixServer = None


def serve_stdio(service: SweepService, in_stream=None, out_stream=None,
                ) -> int:
    """The original JSONL loop as a thin adapter over the shared handler:
    one request per line in, one response line out, engine caches (and
    now the service's result cache) warm for the life of the process."""
    in_stream = in_stream if in_stream is not None else sys.stdin
    out_stream = out_stream if out_stream is not None else sys.stdout
    served = 0
    for line in in_stream:
        if not line.strip():
            continue
        with service.track():
            out_stream.write(json.dumps(service.handle(line)) + "\n")
            out_stream.flush()
        served += 1
    return served

"""``python -m repro.sweep`` — see repro/sweep_cli.py."""

from repro.sweep_cli import main

if __name__ == "__main__":
    main()

"""Stdlib client for the sweep service — library helpers plus a small
CLI used by the CI smoke job and the serve benchmark.

    python -m repro.sweep.client --url 127.0.0.1:8731 \
        --want rows specs/isocap.json specs/isocap.json --concurrency 8

Fires every request concurrently (one thread per request up to
``--concurrency``), prints one response JSON line per request in input
order, and exits nonzero if any response is not ok — so a shell can both
capture parity data and assert health in one call.  ``--stats`` prints
the server's stats document to stderr afterwards (the coalesce counters
the smoke job asserts on).
"""

from __future__ import annotations

import argparse
import json
import socket
import sys
import time
import urllib.error
import urllib.request
from collections.abc import Mapping, Sequence
from concurrent.futures import ThreadPoolExecutor


def _base(url: str) -> str:
    if "://" not in url:
        url = "http://" + url
    return url.rstrip("/")


def http_request(url: str, doc: Mapping, timeout: float = 600.0) -> dict:
    """POST one request document; error responses (HTTP 400) still carry
    the service's JSON error document, which is returned, not raised."""
    data = json.dumps(doc).encode()
    req = urllib.request.Request(
        _base(url) + "/", data=data,
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return json.loads(resp.read().decode())
    except urllib.error.HTTPError as e:
        return json.loads(e.read().decode())


def http_stats(url: str, timeout: float = 60.0) -> dict:
    with urllib.request.urlopen(_base(url) + "/stats",
                                timeout=timeout) as resp:
        return json.loads(resp.read().decode())


def wait_ready(url: str, timeout: float = 60.0) -> bool:
    """Poll /healthz until the server answers (startup gate)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            with urllib.request.urlopen(_base(url) + "/healthz",
                                        timeout=5.0) as resp:
                if resp.status == 200:
                    return True
        except OSError:
            time.sleep(0.1)
    return False


def unix_request(path: str, docs: Sequence[Mapping],
                 timeout: float = 600.0) -> list[dict]:
    """One unix-socket connection, JSONL: send every document, read one
    response line per document (in order)."""
    with socket.socket(socket.AF_UNIX, socket.SOCK_STREAM) as sock:
        sock.settimeout(timeout)
        sock.connect(path)
        f = sock.makefile("rwb")
        for doc in docs:
            f.write((json.dumps(doc) + "\n").encode())
        f.flush()
        return [json.loads(f.readline().decode()) for _ in docs]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep.client",
        description=__doc__.splitlines()[0])
    ap.add_argument("specs", nargs="+",
                    help="spec JSON paths; each becomes one request")
    ap.add_argument("--url", default="127.0.0.1:8731",
                    metavar="HOST:PORT", help="HTTP server address")
    ap.add_argument("--want", action="append", metavar="VIEW",
                    help="requested views (repeatable; default summary)")
    ap.add_argument("--include-dram", action="store_true")
    ap.add_argument("--concurrency", type=int, default=8, metavar="N",
                    help="max in-flight requests (default 8)")
    ap.add_argument("--wait", type=float, default=60.0, metavar="S",
                    help="wait up to S seconds for /healthz first")
    ap.add_argument("--stats", action="store_true",
                    help="print the server stats document to stderr")
    args = ap.parse_args(argv)

    if args.wait and not wait_ready(args.url, args.wait):
        print(f"server at {args.url} not ready", file=sys.stderr)
        return 2
    requests = []
    for path in args.specs:
        with open(path) as f:
            doc = {"spec": json.load(f),
                   "want": args.want or ["summary"],
                   "include_dram": args.include_dram}
        requests.append(doc)
    with ThreadPoolExecutor(max_workers=max(1, args.concurrency)) as pool:
        responses = list(pool.map(
            lambda doc: http_request(args.url, doc), requests))
    ok = True
    for resp in responses:
        print(json.dumps(resp))
        ok = ok and bool(resp.get("ok"))
    if args.stats:
        print(json.dumps(http_stats(args.url), indent=2), file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""End-to-end training driver (example-scale and production-shaped).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 200 --batch 16 --seq 128 --ckpt-dir runs/ckpt

Wires every substrate layer together: config -> model -> sharding on the
host mesh -> data pipeline -> AdamW (+schedule) -> checkpoint manager ->
resilient loop (straggler detection, checkpoint/restart, optional fault
injection, optional gradient compression).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticTokens
from repro.distributed import fault, sharding
from repro.distributed.compression import EFCompressor
from repro.launch import mesh as mesh_mod
from repro.launch.specs import schedule_for
from repro.models import lm as lm_mod
from repro.optim import AdamWConfig, adamw_init, make_train_step


def build_trainer(cfg, *, mesh, batch: int, seq: int, lr_peak: float,
                  total_steps: int, compression: str = "none",
                  remat: str = "full"):
    model = lm_mod.build(cfg)
    if hasattr(model, "remat"):
        model.remat = remat
    opt_cfg = AdamWConfig(schedule=schedule_for(cfg))

    compressor = EFCompressor(kind=compression)

    def loss_fn(params, batch_):
        return model.loss(params, batch_)

    step = make_train_step(loss_fn, opt_cfg)

    params = model.init(jax.random.PRNGKey(0))
    state = adamw_init(params)
    state_sh = sharding.tree_shardings(state, mesh, "param", fsdp=False)
    state = jax.device_put(state, state_sh)
    jit_step = jax.jit(step, in_shardings=(state_sh, None),
                       donate_argnums=(0,))
    return model, state, jit_step, compressor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--fault-at", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, reduced=args.reduced)
    mesh = mesh_mod.make_host_mesh()
    model, state, jit_step, _ = build_trainer(
        cfg, mesh=mesh, batch=args.batch, seq=args.seq, lr_peak=3e-4,
        total_steps=args.steps, compression=args.compression)

    data = SyntheticTokens(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))
    manager = CheckpointManager(args.ckpt_dir, keep=2)
    start, restored = manager.restore_latest(state)
    if restored is not None:
        state = restored
        print(f"resumed from step {start}")

    def step_fn(st, batch_):
        batch_ = {k: jnp.asarray(v) for k, v in batch_.items()}
        st, metrics = jit_step(st, batch_)
        return st, metrics

    t0 = time.time()
    losses = []

    class _LoggingData:
        def batch(self, step):
            return data.batch(step)

    state, log = fault.run_resilient(
        state, _LoggingData(), step_fn, manager, n_steps=args.steps,
        checkpoint_every=args.checkpoint_every, fault_at=args.fault_at)
    for i, m in enumerate(log):
        losses.append(m["loss"])
        if i % args.log_every == 0:
            print(f"step {i:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f}")
    dt = time.time() - t0
    print(f"done: {len(log)} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return losses


if __name__ == "__main__":
    main()

"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md SSRoofline).

Per (arch x shape x mesh):

    compute term    = FLOPs / (chips * peak_FLOP/s)
    memory term     = HBM bytes / (chips * HBM_bw)
    collective term = collective traffic / link_bw   (per device; links
                      operate in parallel, so no further chip division)

FLOPs/HBM bytes come from the analytic accounting (launch/flops.py) since
XLA cost analysis counts scan bodies once; collective traffic comes from
the compiled HLO with while-trip correction (launch/dryrun.py), converted
to link-bytes with per-kind factors: an all-reduce moves ~2x its per-device
operand over the links (reduce-scatter + all-gather phases), an all-gather/
all-to-all/permute ~1x its per-device result, a reduce-scatter ~1x its
input.  Dominant term = the bottleneck; fraction = compute / max(terms).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir runs/dryrun]
Writes runs/roofline.csv and prints the table.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

import repro.configs as configs
from repro.configs.base import SHAPES
from repro.core.report import write_csv, markdown_table
from repro.core.tech import TPU_V5E, TPU_ICI_BW
from repro.launch import flops as flops_mod

PEAK = TPU_V5E.peak_flops          # 197e12 bf16
HBM_BW = TPU_V5E.dram_bw           # 819e9
LINK_BW = TPU_ICI_BW               # 50e9/link

# link-bytes per parsed result-byte, by collective kind
COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def analyze_record(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "status": rec["status"]}
    cfg = configs.get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    acct = flops_mod.account(cfg, shape)
    chips = rec["n_devices"]

    t_compute = acct.flops / (chips * PEAK)
    t_memory = acct.hbm_bytes / (chips * HBM_BW)
    coll_link_bytes = sum(COLL_FACTOR[k] * v
                          for k, v in rec["collectives"]["bytes"].items())
    t_coll = coll_link_bytes / LINK_BW

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    hlo_flops_once = rec["cost"].get("flops", 0.0) or 0.0
    peak_gb = (rec["memory"].get("temp_bytes") or 0) \
        + (rec["memory"].get("argument_bytes") or 0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok", "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "roofline_frac": t_compute / t_bound if t_bound else 0.0,
        "model_flops": acct.model_flops,
        "analytic_flops": acct.flops,
        "useful_ratio": acct.model_flops / acct.flops if acct.flops else 0.0,
        "hlo_flops_per_dev_scan_once": hlo_flops_once,
        "mem_per_dev_gb": peak_gb / 1e9,
        "fits_16gb": peak_gb < 16e9,
        "coll_gb": coll_link_bytes / 1e9,
    }


def load_dir(d: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--out", default="runs/roofline.csv")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = [r for r in (analyze_record(rec) for rec in load_dir(args.dir))
            if r is not None]
    if args.mesh:
        rows = [r for r in rows if r.get("mesh") == args.mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    write_csv(args.out, rows)
    shown = [{k: r.get(k) for k in
              ("arch", "shape", "mesh", "dominant", "roofline_frac",
               "compute_s", "memory_s", "collective_s", "mem_per_dev_gb",
               "status")} for r in rows]
    print(markdown_table(shown))
    print(f"\nwrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()

"""Production mesh builders.

Functions (not module constants) so importing never touches jax device
state.  Production target: TPU-v5e-class pods of 16x16 = 256 chips;
multi-pod doubles along a leading `pod` axis (DP or pipeline across pods).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))

"""Abstract input specs (ShapeDtypeStruct) per (arch x shape) cell.

Everything the dry-run lowers is built here without allocating: params and
optimizer state via jax.eval_shape over the real init functions, inputs as
ShapeDtypeStructs.  Modality frontends are stubs per the assignment: the
Whisper cell feeds precomputed (B, frames, d_model) embeddings; Chameleon's
VQ image tokens are ordinary vocab ids.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import lm as lm_mod
from repro.optim import AdamWConfig, adamw_init, make_train_step
from repro.optim.schedules import cosine, wsd


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s)), "labels": sds((b, s))}
    if cfg.encdec is not None:
        batch["frames"] = sds((b, cfg.encdec.n_frames, cfg.d_model),
                              jnp.bfloat16)
    return batch


def schedule_for(cfg: ArchConfig):
    """MiniCPM trains with WSD (its paper's contribution); others cosine."""
    if "minicpm" in cfg.name:
        return partial(wsd, peak=1e-2, warmup=2000, total=100_000)
    return partial(cosine, peak=3e-4, warmup=2000, total=100_000)


@dataclasses.dataclass
class Cell:
    """One (arch x shape) lowering unit: step fn + abstract args."""

    name: str
    step_fn: object
    abstract_args: tuple
    donate: tuple = ()


def build_cell(cfg: ArchConfig, shape: ShapeSpec) -> Cell:
    model = lm_mod.build(cfg)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        batch = train_batch_specs(cfg, shape)
        opt_cfg = AdamWConfig(schedule=schedule_for(cfg))
        step = make_train_step(model.loss, opt_cfg)
        state = jax.eval_shape(lambda: adamw_init(model.init(key)))
        return Cell(f"{cfg.name}/{shape.name}", step, (state, batch),
                    donate=(0,))

    params = jax.eval_shape(lambda: model.init(key))
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "prefill":
        cache = jax.eval_shape(lambda: model.init_cache(b, s))
        tokens = sds((b, s))
        if cfg.encdec is not None:
            frames = sds((b, cfg.encdec.n_frames, cfg.d_model), jnp.bfloat16)

            def prefill_fn(p, t, c, f):
                return model.prefill(p, t, c, frames=f)
            return Cell(f"{cfg.name}/{shape.name}", prefill_fn,
                        (params, tokens, cache, frames), donate=(2,))

        def prefill_fn(p, t, c):
            return model.prefill(p, t, c)
        return Cell(f"{cfg.name}/{shape.name}", prefill_fn,
                    (params, tokens, cache), donate=(2,))

    # decode: one new token against a cache of length seq_len
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    tokens = sds((b, 1))
    index = sds((), jnp.int32)
    if cfg.encdec is not None:
        enc = sds((b, cfg.encdec.n_frames, cfg.d_model), jnp.bfloat16)

        def decode_fn(p, t, c, i, e):
            return model.decode_step(p, t, c, i, enc_out=e)
        return Cell(f"{cfg.name}/{shape.name}", decode_fn,
                    (params, tokens, cache, index, enc), donate=(2,))

    def decode_fn(p, t, c, i):
        return model.decode_step(p, t, c, i)
    return Cell(f"{cfg.name}/{shape.name}", decode_fn,
                (params, tokens, cache, index), donate=(2,))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Public helper (spec-mandated name): the model-input stand-ins."""
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    b = shape.global_batch
    if shape.kind == "prefill":
        return {"tokens": sds((b, shape.seq_len))}
    return {"tokens": sds((b, 1)), "index": sds((), jnp.int32)}

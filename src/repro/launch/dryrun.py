import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production mesh and extract the roofline inputs.

MUST be run as a script/module (the XLA_FLAGS line above has to execute
before any jax import anywhere in the process):

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single --out runs/dryrun

Outputs one JSON per cell: per-device HLO FLOPs/bytes, collective bytes by
kind, memory analysis, compile wall time.  launch/roofline.py turns these
into EXPERIMENTS.md SS Roofline rows.
"""

import argparse
import json
import re
import time

import jax

import repro.configs as configs
from repro.configs.base import SHAPES
from repro.distributed import sharding
from repro.launch import mesh as mesh_mod
from repro.launch.specs import build_cell

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(f8e4m3fn|f8e5m2|bf16|f16|f32|f64|s8|s16|s32|s64|"
                       r"u8|u16|u32|u64|pred)\[([0-9,]*)\]")
_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
          "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8, "u64": 8}


_OP_RE = re.compile(
    r"\s(" + "|".join(COLLECTIVE_KINDS) + r")(-start|-done)?\(")
_WHILE_RE = re.compile(
    r"while\(.*?(?:condition=(%[\w.\-]+).*?body=(%[\w.\-]+)"
    r"|body=(%[\w.\-]+).*?condition=(%[\w.\-]+))")
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\(", re.M)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dtype]
    return total


def _split_computations(hlo_text: str) -> dict[str, str]:
    """name -> body text, for every computation in the module."""
    comps: dict[str, str] = {}
    name = None
    buf: list[str] = []
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and "{" in line and "(" in line:
            m = _COMP_RE.match(line)
            if m:
                if name is not None:
                    comps[name] = "\n".join(buf)
                name = m.group(2)
                buf = []
                continue
        if line.startswith("}"):
            if name is not None:
                comps[name] = "\n".join(buf)
            name = None
            buf = []
            continue
        if name is not None:
            buf.append(line)
    if name is not None:
        comps[name] = "\n".join(buf)
    return comps


def collective_bytes(hlo_text: str) -> dict:
    """Collective bytes/counts by kind, with while-loop bodies (lax.scan
    layers, sequence scans) multiplied by their trip counts — XLA's text
    emits each body once, so a flat parse undercounts scanned models."""
    comps = _split_computations(hlo_text)

    def block_stats(body: str):
        own_b = {k: 0 for k in COLLECTIVE_KINDS}
        own_c = {k: 0 for k in COLLECTIVE_KINDS}
        children: list[tuple[str, str]] = []  # (cond, body)
        for line in body.splitlines():
            stripped = line.strip()
            if " = " not in stripped:
                continue
            rhs = stripped.split(" = ", 1)[1]
            m = _OP_RE.search(rhs)
            if m and m.group(2) != "-done":   # count start ops once
                kind = m.group(1)
                own_b[kind] += _shape_bytes(rhs[:m.start()])
                own_c[kind] += 1
            wm = _WHILE_RE.search(rhs)
            if wm:
                cond = wm.group(1) or wm.group(4)
                wbody = wm.group(2) or wm.group(3)
                children.append((cond, wbody))
        return own_b, own_c, children

    memo: dict[str, tuple[dict, dict]] = {}

    def resolve(name: str) -> tuple[dict, dict]:
        if name in memo:
            return memo[name]
        body = comps.get(name, "")
        b, c, children = block_stats(body)
        for cond_name, body_name in children:
            consts = [int(x) for x in
                      _CONST_RE.findall(comps.get(cond_name, ""))]
            trip = max(consts) if consts else 1
            cb, cc = resolve(body_name)
            for k in COLLECTIVE_KINDS:
                b[k] += trip * cb[k]
                c[k] += trip * cc[k]
        memo[name] = (b, c)
        return b, c

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            entry = m.group(2) if m else None
            break
    if entry is None:
        return {"bytes": {k: 0 for k in COLLECTIVE_KINDS},
                "counts": {k: 0 for k in COLLECTIVE_KINDS}}
    b, c = resolve(entry)
    return {"bytes": b, "counts": c}


def shardings_for(cell, mesh, fsdp: bool):
    """Build in_shardings matching the cell's abstract args."""
    ins = []
    for i, arg in enumerate(cell.abstract_args):
        leaves = jax.tree.leaves(arg)
        if not leaves:
            ins.append(None)
            continue
        # classify by position: arg0 = state/params, caches contain 'seg'
        if i == 0:
            ins.append(sharding.tree_shardings(arg, mesh, "param", fsdp=fsdp))
        else:
            paths = [sharding._path_str(p) for p, _ in
                     jax.tree_util.tree_flatten_with_path(arg)[0]]
            if any("seg" in p for p in paths):
                ins.append(sharding.tree_shardings(arg, mesh, "cache"))
            else:
                ins.append(sharding.batch_shardings(arg, mesh))
    return tuple(ins)


def run_cell(arch: str, shape_name: str, multi_pod: bool, fsdp: bool,
             out_dir: str | None, reduced: bool = False,
             act_shard: bool = False, seq_parallel: bool = False,
             remat: str = "full", kv_fp8: bool = False,
             tag: str = "") -> dict:
    cfg = configs.get(arch, reduced=reduced)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "status": "skipped(full-attention)"}
        _emit(rec, out_dir, tag)
        return rec

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    if act_shard:
        sharding.enable_activation_sharding(mesh, seq_parallel=seq_parallel)
    import jax.numpy as jnp
    import repro.models.lm as _lm
    _orig_build = _lm.build
    if remat != "full" or kv_fp8:
        def _build(cfg_):
            m = _orig_build(cfg_)
            m.remat = remat
            if kv_fp8:
                m.kv_cache_dtype = jnp.float8_e4m3fn
            return m
        _lm.build = _build
    try:
        cell = build_cell(cfg, shape)
    finally:
        _lm.build = _orig_build
    in_sh = shardings_for(cell, mesh, fsdp)

    t0 = time.time()
    jitted = jax.jit(cell.step_fn, in_shardings=in_sh,
                     donate_argnums=cell.donate)
    lowered = jitted.lower(*cell.abstract_args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # noqa: BLE001 — backend-dependent API
        mem_rec = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        cost_rec = {k: v for k, v in cost.items()
                    if k in ("flops", "bytes accessed", "transcendentals")
                    or k.startswith("bytes accessed")}
    except Exception as e:  # noqa: BLE001
        cost_rec = {"error": str(e)}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    sharding.enable_activation_sharding(None)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": mesh.size,
        "fsdp": fsdp,
        "act_shard": act_shard, "seq_parallel": seq_parallel,
        "remat": remat, "kv_fp8": kv_fp8,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "cost": cost_rec,
        "collectives": coll,
        "hlo_bytes": len(hlo),
    }
    _emit(rec, out_dir, tag)
    return rec


def _emit(rec: dict, out_dir: str | None, tag: str = ""):
    line = (f"[{rec['arch']} x {rec['shape']} x {rec['mesh']}{tag}] "
            f"{rec['status']}"
            + (f" compile={rec.get('compile_s')}s "
               f"flops={rec.get('cost', {}).get('flops')}"
               if rec["status"] == "ok" else ""))
    print(line, flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--fsdp", action="store_true", default=None)
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--act-shard", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--kv-fp8", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args()
    fsdp = args.fsdp
    if fsdp is None:
        fsdp = configs.get(args.arch).param_count() > 8e9
    run_cell(args.arch, args.shape, args.mesh == "multi", fsdp, args.out,
             reduced=args.reduced, act_shard=args.act_shard,
             seq_parallel=args.seq_parallel, remat=args.remat,
             kv_fp8=args.kv_fp8, tag=args.tag)


if __name__ == "__main__":
    main()

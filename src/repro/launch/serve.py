"""Batched serving driver: prefill + decode loop with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import lm as lm_mod


def generate(model, params, prompts, max_seq: int, gen: int,
             frames=None):
    b, prompt_len = prompts.shape
    cache = model.init_cache(b, max_seq)
    kw = {"frames": frames} if frames is not None else {}
    logits, cache = model.prefill(params, prompts, cache, **kw)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    out = [tok]

    decode = jax.jit(model.decode_step)
    for i in range(gen - 1):
        logits, cache = decode(params, tok, cache,
                               jnp.asarray(prompt_len + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, reduced=args.reduced)
    model = lm_mod.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    frames = None
    if cfg.encdec is not None:
        frames = jnp.zeros((args.batch, cfg.encdec.n_frames, cfg.d_model),
                           jnp.bfloat16)
    t0 = time.time()
    toks = generate(model, params, prompts,
                    args.prompt_len + args.gen, args.gen, frames)
    dt = time.time() - t0
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(toks[0])
    return toks


if __name__ == "__main__":
    main()

"""Analytic FLOPs / bytes accounting per (arch x shape) cell.

XLA's cost analysis counts lax.scan bodies once (a while op), so compiled
FLOPs structurally undercount scanned models; the roofline's compute term
therefore uses these closed-form counts (6*N*D style, with explicit
attention/MoE/SSM terms), and the HLO numbers are reported alongside as
diagnostics (EXPERIMENTS.md SS Dry-run notes the discrepancy and the
collective-bytes parser's while-trip correction).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class Accounting:
    flops: float            # total FLOPs for the step (global)
    model_flops: float      # 6*N_active*D (train) / 2*N_active*D (serve)
    hbm_bytes: float        # estimated HBM traffic for the step (global)
    param_bytes: float      # parameter bytes read per step
    param_count: float
    active_param_count: float
    kv_read_bytes: float
    kv_write_bytes: float


def active_params(cfg: ArchConfig) -> float:
    """Params touched per token (MoE: shared + top-k routed only)."""
    if cfg.moe is None:
        return float(cfg.param_count())
    m = cfg.moe
    d = cfg.d_model
    emb = cfg.vocab * d * (1 if cfg.tied_embeddings else 2)
    if cfg.mla is not None:
        a = cfg.mla
        attn = (d * a.q_lora_rank
                + a.q_lora_rank * cfg.n_heads * (a.qk_nope_dim + a.qk_rope_dim)
                + d * (a.kv_lora_rank + a.qk_rope_dim)
                + a.kv_lora_rank * cfg.n_heads * (a.qk_nope_dim + a.v_head_dim)
                + cfg.n_heads * a.v_head_dim * d)
    else:
        attn = 2 * d * cfg.n_heads * cfg.head_dim \
            + 2 * d * cfg.n_kv_heads * cfg.head_dim
    ffn_moe = 3 * d * m.d_expert * (m.top_k + m.n_shared)
    ffn_dense = 3 * d * m.dense_d_ff
    n_moe = cfg.n_layers - m.first_dense_layers
    return float(emb + cfg.n_layers * attn + n_moe * ffn_moe
                 + m.first_dense_layers * ffn_dense)


def _attn_flops(cfg: ArchConfig, batch: int, s_q: int, s_kv: int,
                causal: bool) -> float:
    """SDPA flops: QK^T + PV, 2 MACs each."""
    if cfg.rwkv:
        # WKV recurrence: ~4 state ops of (hd x hd) per head per token
        hd = cfg.d_model // cfg.n_heads
        return 4.0 * batch * s_q * cfg.n_heads * hd * hd * 2
    h = cfg.n_heads
    hd = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) if cfg.mla else cfg.head_dim
    frac = 0.5 if (causal and s_q == s_kv) else 1.0
    base = 4.0 * batch * s_q * s_kv * h * hd * frac
    if cfg.ssm is not None:
        # hybrid: SWA on most layers
        glb = len(cfg.ssm.global_attn_layers)
        swa = cfg.n_layers - glb
        w = min(cfg.ssm.sliding_window, s_kv)
        per_layer = 4.0 * batch * s_q * h * hd
        attn = per_layer * (glb * s_kv * frac + swa * w)
        ssm = 6.0 * batch * s_q * cfg.d_model * cfg.ssm.state_dim * cfg.n_layers
        return attn + ssm
    return base * cfg.n_layers


def account(cfg: ArchConfig, shape: ShapeSpec) -> Accounting:
    n_total = float(cfg.param_count())
    n_active = active_params(cfg)
    b, s = shape.global_batch, shape.seq_len
    is_train = shape.kind == "train"
    tokens = b * (1 if shape.is_decode else s)

    matmul_fwd = 2.0 * tokens * n_active
    if shape.is_decode:
        attn = _attn_flops(cfg, b, 1, s, causal=True)
    else:
        attn = _attn_flops(cfg, b, s, s, causal=True)
    if cfg.encdec is not None and not shape.is_decode:
        # encoder + cross attention over the frame context
        f = cfg.encdec.n_frames
        attn += _attn_flops(cfg, b, f, f, causal=False) \
            + 4.0 * b * s * f * cfg.n_heads * cfg.head_dim * cfg.n_layers

    fwd = matmul_fwd + attn
    mult = 3.0 if is_train else 1.0        # fwd + dgrad + wgrad
    flops = fwd * mult
    if cfg.mtp and is_train:
        flops *= 1.0 + 1.5 / cfg.n_layers  # one extra block + head
    model_flops = (6.0 if is_train else 2.0) * n_active * tokens

    # KV cache traffic (serving)
    kv_read = kv_write = 0.0
    if shape.is_decode:
        if cfg.rwkv:
            state = cfg.n_layers * cfg.n_heads \
                * (cfg.d_model // cfg.n_heads) ** 2 * 2
            kv_read = kv_write = float(b * state * 2)
        elif cfg.mla is not None:
            per_tok = cfg.n_layers * (cfg.mla.kv_lora_rank
                                      + cfg.mla.qk_rope_dim) * 2
            kv_read, kv_write = float(b * s * per_tok), float(b * per_tok)
        elif cfg.ssm is not None:
            w = cfg.ssm.sliding_window
            glb = len(cfg.ssm.global_attn_layers)
            swa = cfg.n_layers - glb
            per_l = cfg.n_kv_heads * cfg.head_dim * 2 * 2
            kv_read = float(b * (glb * s + swa * w) * per_l
                         + b * cfg.n_layers * cfg.d_model
                         * cfg.ssm.state_dim * 4)
            kv_write = float(b * cfg.n_layers * per_l
                         + b * cfg.n_layers * cfg.d_model
                         * cfg.ssm.state_dim * 4)
        else:
            per_l = cfg.n_kv_heads * cfg.head_dim * 2 * 2
            kv_read, kv_write = float(b * s * cfg.n_layers * per_l), \
                float(b * cfg.n_layers * per_l)
    elif shape.kind == "prefill":
        per_l = ((cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
                 if cfg.mla else cfg.n_kv_heads * cfg.head_dim * 2 * 2)
        kv_write = float(b * s * cfg.n_layers * per_l)

    pbytes = n_total * (4.0 if is_train else 2.0)
    act_bytes = 16.0 * cfg.n_layers * tokens * cfg.d_model * 2.0 * \
        (1.0 if is_train else 0.25)
    hbm = pbytes * (6.0 if is_train else 1.0) + act_bytes + kv_read + kv_write
    return Accounting(flops=flops, model_flops=model_flops, hbm_bytes=hbm,
                      param_bytes=pbytes, param_count=n_total,
                      active_param_count=n_active,
                      kv_read_bytes=kv_read, kv_write_bytes=kv_write)

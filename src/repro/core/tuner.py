"""EDAP-optimal cache tuning — paper Algorithm 1.

For each (memory technology, capacity): sweep every optimization target
(read/write latency, read/write energy, read/write EDP, area, leakage) and
every access type; each (target, access) pair nominates the design point
that optimizes it; keep the nominee with the smallest EDAP.  This mirrors
the paper's use of NVSim's optimization-target knob and guarantees each
technology is compared at its own best configuration ("a fair comparison
that encompasses all and not just one of the design constraint dimensions").

Execution: the sweep itself runs on the batched engine (core/engine.py) —
one jitted evaluation of the whole organization grid, then a masked argmin
per (target, access).  ``tune_loop`` preserves the original scalar walk
(one ``CacheModel.evaluate`` per design point) as the parity reference and
the benchmark baseline.
"""

from __future__ import annotations

import functools
from collections.abc import Callable, Iterable

import numpy as np

from repro.core import engine
from repro.core.cachemodel import ASSOC  # noqa: F401  (re-export convenience)
from repro.core.cachemodel import ACCESS_TYPES, CacheDesign, CacheModel
from repro.core.calibration import ISO_AREA_TOLERANCE
from repro.core.tech import TechNode, TECH_16NM

# NVSim optimization targets (paper Algorithm 1's set O).  The batched
# selection (engine.DesignTable.tuned_index) follows this exact order.
OPT_TARGETS: dict[str, Callable[[CacheDesign], float]] = {
    "read_latency": lambda d: d.read_latency_s,
    "write_latency": lambda d: d.write_latency_s,
    "read_energy": lambda d: d.read_energy_j,
    "write_energy": lambda d: d.write_energy_j,
    "read_edp": lambda d: d.read_latency_s * d.read_energy_j,
    "write_edp": lambda d: d.write_latency_s * d.write_energy_j,
    "area": lambda d: d.area_mm2,
    "leakage": lambda d: d.leakage_w,
}


def tune(model: CacheModel, capacity_bytes: int) -> CacheDesign:
    """Algorithm 1 for one (mem, capacity): min-EDAP over target nominees.

    Evaluates the organization grid as a single-element-technology batch on
    the engine, honoring the model's (possibly trial) bitcell/calibration —
    the calibration fixed point calls this with unfitted multipliers.
    """
    table = engine.sweep((capacity_bytes,), mems=(model.mem,),
                         cells=(model.cell,), cals=(model.cal,),
                         nodes=model.node)
    return table.tuned(model.mem, capacity_bytes)


def tune_loop(model: CacheModel, capacity_bytes: int) -> CacheDesign:
    """Original scalar Algorithm 1 (kept as parity/benchmark reference)."""
    designs = [model.evaluate_scalar(capacity_bytes, org)
               for org in model.design_space(capacity_bytes)]
    if not designs:
        raise ValueError(f"empty design space at {capacity_bytes} bytes")
    best: CacheDesign | None = None
    for metric in OPT_TARGETS.values():
        for access in ACCESS_TYPES:
            pool = [d for d in designs if d.org.access == access]
            nominee = min(pool, key=metric)
            if best is None or nominee.edap() < best.edap():
                best = nominee
    return best


@functools.lru_cache(maxsize=None)
def _tuned_design_cached(mem: str, capacity_bytes: int,
                         node: TechNode) -> CacheDesign:
    table = engine.design_table((mem,), (capacity_bytes,), nodes=(node,))
    return table.tuned(mem, capacity_bytes)


def tuned_design(mem: str, capacity_mb: float,
                 node: TechNode = TECH_16NM) -> CacheDesign:
    """Convenience: EDAP-tuned design for `mem` at `capacity_mb` (memoized:
    every caller of the same (mem, capacity, node) shares one tuned sweep)."""
    return _tuned_design_cached(mem, int(capacity_mb * 2**20), node)


def iso_area_capacity(mem: str, sram_capacity_mb: float = 3.0,
                      search_mb: Iterable[int] = range(1, 65),
                      node: TechNode = TECH_16NM) -> int:
    """Largest (integer-MB) capacity of `mem` fitting the SRAM area budget.

    Paper §III-B scenario (ii): reuse the SRAM cache's area for a larger
    NVM cache.  Tolerance: the paper's own 10 MB SOT point is 5.64 mm^2 vs
    5.53 mm^2 SRAM (+2%), so the budget is 1.02x the SRAM area.

    Area is organization-independent, so feasibility is one vectorized mask
    over the engine's area row — no per-capacity tuning.  Both the SRAM
    budget and the search run at `node`.
    """
    budget = tuned_design("sram", sram_capacity_mb, node).area_mm2 \
        * ISO_AREA_TOLERANCE
    search = tuple(search_mb)
    caps_bytes = tuple(mb * 2**20 for mb in search)
    areas = engine.design_table((mem,), caps_bytes, nodes=(node,)).areas(mem)
    feasible = np.asarray(search)[areas <= budget]
    if feasible.size == 0:
        raise ValueError(f"no iso-area capacity for {mem}")
    return int(feasible.max())


def table2() -> dict[str, CacheDesign]:
    """Reproduce paper Table II: 3 MB iso-capacity columns for all three
    technologies plus the iso-area columns for the MRAM flavors."""
    out = {mem: tuned_design(mem, 3) for mem in ("sram", "stt", "sot")}
    for mem in ("stt", "sot"):
        cap = iso_area_capacity(mem)
        out[f"{mem}_isoarea"] = tuned_design(mem, cap)
    return out

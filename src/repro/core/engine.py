"""Batched design-space engine — the full NVSim sweep as one computation.

DeepNVM++'s Algorithm 1 is an exhaustive sweep: every internal cache
organization (banks x rows x cols), every NVSim access type, every
optimization target, for every (technology, capacity) pair.  The scalar
path (core/cachemodel.py) walks that space one design point at a time;
this module evaluates it as a single batched tensor computation.

Representation: structure-of-arrays.  The organization grid is four flat
arrays (banks, rows, cols, access index) in exactly the order the scalar
``CacheModel.design_space`` iterates (itertools.product over the same
choices), so argmin tie-breaking matches the scalar ``min``.  Technology
nodes are rows of a node parameter matrix (NODE_FIELDS: the TechNode
supply/drive/sense/cell-area parameters followed by the node-derived
periphery building blocks of ``cachemodel.periphery``) and, per node,
technologies are rows of two parameter matrices — the characterized
bitcell vector (bitcell.ARRAY_FIELDS, node-dependent through the fin
sweep) and the calibration vector (CAL_FIELDS, node-dependent through the
derivation rule of calibration.get) — with capacities a further axis.
One jitted function maps the cross product

    [node] x [tech] x [cap] x [org]  ->  PPA tensors of shape [n, m, c, o]

re-expressing every latency/energy/leakage/area equation of cachemodel.py
as a pure array function.  Float64 throughout (jax.experimental.enable_x64)
so the batched numbers agree with the scalar Python-float path to the last
few ulps, keeping the Table I/II calibration anchors intact.  A cross-node
DTCO sweep (Mishty & Sadi 2023 run their SOT-MRAM study per node by hand)
is therefore one ``design_table`` call with several nodes.

On top of the PPA tensors, :class:`DesignTable` implements Algorithm 1 as a
masked argmin per (optimization target, access type) — the same nominee
pool and the same first-strict-minimum EDAP tie-breaking as the scalar
``tuner.tune`` — plus vectorized feasibility queries (iso-area capacity
search) that need no per-capacity tuning at all.

``design_table`` memoizes fully-calibrated tables per (nodes, mems,
capacities) so every consumer — tuner, isocap, isoarea, scaling, dtco,
benchmarks — shares one evaluation of the sweep.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import bitcell as bitcell_mod
from repro.core.cachemodel import (
    ACCESS_TYPES,
    ASSOC,
    BANK_CHOICES,
    COL_CHOICES,
    FLIP_P,
    LINE_BYTES,
    PERIPHERY_FIELDS,
    ROW_CHOICES,
    TAG_BITS,
    CacheDesign,
    CacheOrg,
    _SRAM_LAT_STRESS_EXP,
    _SRAM_LEAK_STRESS_EXP,
    _STRESS_ANCHOR_MB,
    periphery,
)
from repro.core.tech import TechNode, TECH_16NM

MEMS = ("sram", "stt", "sot")

# Calibration parameters consumed by the PPA equations, in the order they
# are packed into the per-technology calibration matrix.
CAL_FIELDS = (
    "peri_area_lin",
    "peri_area_sqrt",
    "leak_lin",
    "leak_sqrt",
    "k_read_lat",
    "k_write_lat",
    "k_read_e",
    "k_write_e",
)

# TechNode parameters the equations read, followed by the node-derived
# periphery building blocks (cachemodel.Periphery, in PERIPHERY_FIELDS
# order) — packed as one per-node vector so a non-default node stays a
# runtime input, not a recompile.
#
# Bit-identity note: the kernel is traced twice, switched by the static
# ``anchor_peri`` flag.  The anchor trace binds the periphery as Python
# floats — producing the exact HLO the pre-refactor kernel compiled to,
# because XLA's fusion/codegen is last-ulp sensitive to whether a
# multiplicand is a literal or a broadcast tensor — and the node trace
# reads the same quantities from the ``peri`` matrix.  ``sweep`` routes
# each node row by *value* (anchor-periphery rows to the anchor trace),
# so the 16 nm anchor stays bit-identical to the scalar calibration while
# scaled nodes remain runtime tensor inputs: two compilations total, ever.
TECHNODE_FIELDS = ("vdd_v", "ion_per_fin_a", "sense_voltage_v",
                   "sram_cell_area_um2")
NODE_FIELDS = TECHNODE_FIELDS + PERIPHERY_FIELDS
_N_TECHNODE = len(TECHNODE_FIELDS)

# The anchor periphery as trace-time constants for the anchor_peri trace.
_PERI_16NM_ROW = tuple(
    getattr(periphery(TECH_16NM), f) for f in PERIPHERY_FIELDS)

# --- structure-of-arrays organization grid ---------------------------------
# Same product order as CacheModel.design_space so masked argmins break ties
# identically to the scalar min() over the generated sequence.
_ORG_TUPLES = tuple(itertools.product(
    BANK_CHOICES, ROW_CHOICES, COL_CHOICES, range(len(ACCESS_TYPES))))
ORG_BANKS = np.array([t[0] for t in _ORG_TUPLES], dtype=np.int64)
ORG_ROWS = np.array([t[1] for t in _ORG_TUPLES], dtype=np.int64)
ORG_COLS = np.array([t[2] for t in _ORG_TUPLES], dtype=np.int64)
ORG_ACCESS = np.array([t[3] for t in _ORG_TUPLES], dtype=np.int64)
N_ORGS = len(_ORG_TUPLES)

ORGS = tuple(CacheOrg(banks=int(b), rows=int(r), cols=int(c),
                      access=ACCESS_TYPES[a])
             for b, r, c, a in _ORG_TUPLES)

_SEQ = ACCESS_TYPES.index("sequential")
_FAST = ACCESS_TYPES.index("fast")


def valid_mask(capacities_bytes: np.ndarray) -> np.ndarray:
    """[c, o] bool — CacheModel.design_space's feasibility filters."""
    caps = np.asarray(capacities_bytes, dtype=np.int64)[:, None]
    bits = caps * 8
    brc = (ORG_BANKS * ORG_ROWS * ORG_COLS)[None, :]
    degenerate = brc > 4 * bits
    # scalar path: float division, so mirror it bit-for-bit
    too_few = bits.astype(np.float64) / brc.astype(np.float64) > 4096
    return ~(degenerate | too_few)


@functools.partial(jax.jit, static_argnames="anchor_peri")
def _ppa_kernel(cell, cal, is_sram, node, peri, caps_bytes, banks, rows,
                cols, acc, *, anchor_peri):
    """PPA equations of cachemodel.py as one batched map.

    cell [n, m, 7] (bitcell.ARRAY_FIELDS), cal [n, m, 8] (CAL_FIELDS),
    is_sram [m], node [n, 4] (TECHNODE_FIELDS), peri [n, 7]
    (PERIPHERY_FIELDS), caps_bytes [c], banks/rows/cols/acc [o]
    ->  dict of [n, m, c, o] / [n, m, c] tensors.

    Every expression keeps the scalar path's operation order so float64
    results match the Python-float reference to the last ulps.  The static
    ``anchor_peri`` flag selects where the periphery comes from: the 16 nm
    constants as trace-time literals (bit-identical anchor codegen; ``peri``
    is ignored) or the ``peri`` matrix (scaled nodes, runtime input).
    """
    # broadcast axes: n = node, m = technology, c = capacity, o = org
    def M(x):      # [n, m] -> [n, m, 1, 1]
        return x[:, :, None, None]

    def N(x):      # [n] -> [n, 1, 1, 1]
        return x[:, None, None, None]

    (vdd, ion, sense_v, sram_cell_um2) = (N(node[:, i])
                                          for i in range(node.shape[1]))
    if anchor_peri:
        (t_gate_s, t_sense_amp_s, e_gate_j, htree_ns_per_mm, htree_pj_per_mm_bit,
         c_bitline_per_row_f, c_wordline_per_col_f) = _PERI_16NM_ROW
    else:
        (t_gate_s, t_sense_amp_s, e_gate_j, htree_ns_per_mm, htree_pj_per_mm_bit,
         c_bitline_per_row_f, c_wordline_per_col_f) = (
            N(peri[:, i]) for i in range(peri.shape[1]))
    (i_read, sense_lat, sense_e, wlat_avg, we_avg, area_norm,
     cell_leak) = (M(cell[:, :, i]) for i in range(cell.shape[2]))
    (peri_area_lin, peri_area_sqrt, leak_lin, leak_sqrt,
     k_read_lat, k_write_lat, k_read_e, k_write_e) = (
        M(cal[:, :, i]) for i in range(cal.shape[2]))
    sram = is_sram[None, :, None, None]

    cap = caps_bytes[None, None, :, None].astype(jnp.float64)  # [1, 1, c, 1]
    cap_mb = cap / 2**20
    data_bits = cap * 8
    tag_bits = jnp.floor(cap / LINE_BYTES) * TAG_BITS
    bits_total = data_bits + tag_bits

    banks = banks[None, None, None, :].astype(jnp.float64)    # [1, 1, 1, o]
    rows = rows[None, None, None, :].astype(jnp.float64)
    cols = cols[None, None, None, :].astype(jnp.float64)
    acc = acc[None, None, None, :]

    # -- geometry (CacheModel._subarrays / area_mm2 / _htree_mm) -----------
    n_sub = jnp.maximum(1.0, jnp.ceil(bits_total / (rows * cols)))
    cell_um2 = area_norm * sram_cell_um2
    array_area = bits_total * cell_um2 * 1e-6 / 0.85          # mm2_from_um2
    peri_area = peri_area_lin * cap_mb + peri_area_sqrt * jnp.sqrt(cap_mb)
    area = array_area + peri_area                             # [n, m, c, 1]
    htree_mm = jnp.sqrt(area) * (1.0 + jnp.log2(banks) / 8.0)

    stress_base = cap / 2**20 / _STRESS_ANCHOR_MB
    stress_lat = jnp.where(sram, stress_base ** _SRAM_LAT_STRESS_EXP, 1.0)
    stress_leak = jnp.where(sram, stress_base ** _SRAM_LEAK_STRESS_EXP, 1.0)

    # -- latency -----------------------------------------------------------
    decoder = jnp.log2(rows) * t_gate_s
    c_wl = cols * c_wordline_per_col_f
    wordline = 2.2 * c_wl * (vdd / ion) * 0.05
    c_bl = rows * c_bitline_per_row_f
    bitline = c_bl * sense_v / i_read + sense_lat + t_sense_amp_s
    routing = 2.0 * t_gate_s * jnp.log2(jnp.maximum(2.0, n_sub))
    ht_lat = htree_mm * htree_ns_per_mm * 1e-9

    array_t = decoder + wordline + bitline
    tag_t = decoder + wordline + 0.4 * bitline
    lat_seq = ht_lat + routing + tag_t + array_t + 2 * t_gate_s
    lat_fast = ht_lat + routing + array_t + t_gate_s
    lat_norm = ht_lat + routing + jnp.maximum(tag_t, array_t) + 3 * t_gate_s
    read_lat = jnp.where(acc == _SEQ, lat_seq,
                         jnp.where(acc == _FAST, lat_fast, lat_norm))
    read_lat = read_lat * k_read_lat * stress_lat
    write_lat = (ht_lat + routing + decoder + wordline + wlat_avg) \
        * k_write_lat * stress_lat

    # -- energy ------------------------------------------------------------
    line_bits = LINE_BYTES * 8
    ways_sensed = jnp.where(acc == _SEQ, 1.0, float(ASSOC))
    sense = line_bits * ways_sensed * sense_e
    bl_read = line_bits * ways_sensed * c_bl * vdd * vdd
    ht_e = htree_mm * htree_pj_per_mm_bit * 1e-12 * line_bits
    dec_e = jnp.log2(rows) * 64 * e_gate_j
    route_e = n_sub * 4 * e_gate_j
    read_e = (sense + bl_read + ht_e + dec_e + route_e) * k_read_e

    flips = line_bits * jnp.where(sram, 1.0, FLIP_P)
    cellw = flips * we_avg
    bl_write = line_bits * c_bl * vdd * vdd * 2.0
    write_e = (cellw + bl_write + ht_e + dec_e + route_e) * k_write_e

    # -- leakage (org-independent, like CacheModel.leakage_w) --------------
    cells_leak = bits_total * cell_leak * stress_leak
    peri_leak = leak_lin * cap_mb + leak_sqrt * jnp.sqrt(cap_mb)
    leakage = (cells_leak + peri_leak)[..., 0]                # [n, m, c]

    return dict(
        read_latency_s=read_lat,
        write_latency_s=write_lat,
        read_energy_j=read_e,
        write_energy_j=write_e,
        leakage_w=leakage,
        area_mm2=area[..., 0],
    )


# Public pure-function entry point to the batched PPA equations.  This is
# the *same* jitted callable the memoized ``design_table`` path dispatches
# (not a wrapper around it), so any consumer calling it — the inverse
# design's differentiable lowering, parity tests, the bench_engine retrace
# counter — provably shares the exact compiled HLO and trace cache with
# the memoized path: a new caller can never introduce a third trace.
# Differentiable in ``cell``/``cal``/``node``/``peri`` (jax.grad composes
# through jit), which is what repro.inverse builds on.
ppa_fn = _ppa_kernel


def node_row(node: TechNode) -> np.ndarray:
    """One [NODE_FIELDS] float64 row of the node parameter matrix: the
    TechNode supply/drive/sense/cell-area parameters followed by the
    node-derived periphery bundle — the per-node runtime input of
    ``ppa_fn`` (split as ``row[:len(TECHNODE_FIELDS)]`` / the rest)."""
    return np.concatenate([
        np.array([getattr(node, f) for f in TECHNODE_FIELDS],
                 dtype=np.float64),
        periphery(node).as_array()])


@dataclasses.dataclass(frozen=True)
class DesignTable:
    """Evaluated (node x tech x capacity x organization) sweep + Algorithm 1.

    Every accessor takes an optional ``node``; a single-node table (the
    common case) resolves it implicitly, a multi-node (DTCO) table requires
    it — there is no silent default to the first node.
    """

    nodes: tuple[TechNode, ...]
    mems: tuple[str, ...]
    capacities_bytes: tuple[int, ...]
    read_latency_s: np.ndarray     # [n, m, c, o]
    write_latency_s: np.ndarray    # [n, m, c, o]
    read_energy_j: np.ndarray      # [n, m, c, o]
    write_energy_j: np.ndarray     # [n, m, c, o]
    leakage_w: np.ndarray          # [n, m, c]
    area_mm2: np.ndarray           # [n, m, c]
    valid: np.ndarray              # [c, o] bool (node/tech-independent)

    # -- indexing ----------------------------------------------------------

    def _node_index(self, node: TechNode | None) -> int:
        if node is None:
            if len(self.nodes) == 1:
                return 0
            raise ValueError(
                f"table spans {len(self.nodes)} nodes "
                f"({', '.join(nd.name for nd in self.nodes)}); pass node=")
        try:
            return self.nodes.index(node)
        except ValueError:
            raise ValueError(f"node {node.name!r} not in table") from None

    def _nmc(self, mem: str, capacity_bytes: int,
             node: TechNode | None = None) -> tuple[int, int, int]:
        return (self._node_index(node), self.mems.index(mem),
                self.capacities_bytes.index(capacity_bytes))

    def design(self, mem: str, capacity_bytes: int, org_index: int,
               node: TechNode | None = None) -> CacheDesign:
        """Materialize one design point as the scalar-API dataclass."""
        n, m, c = self._nmc(mem, capacity_bytes, node)
        o = org_index
        return CacheDesign(
            mem=mem,
            capacity_bytes=capacity_bytes,
            org=ORGS[o],
            read_latency_s=float(self.read_latency_s[n, m, c, o]),
            write_latency_s=float(self.write_latency_s[n, m, c, o]),
            read_energy_j=float(self.read_energy_j[n, m, c, o]),
            write_energy_j=float(self.write_energy_j[n, m, c, o]),
            leakage_w=float(self.leakage_w[n, m, c]),
            area_mm2=float(self.area_mm2[n, m, c]),
        )

    def designs(self, mem: str, capacity_bytes: int,
                node: TechNode | None = None) -> list[CacheDesign]:
        """All valid design points, in scalar design_space order."""
        _, _, c = self._nmc(mem, capacity_bytes, node)
        return [self.design(mem, capacity_bytes, o, node=node)
                for o in np.flatnonzero(self.valid[c])]

    # -- Algorithm 1 -------------------------------------------------------

    def edap(self, mem: str, capacity_bytes: int,
             node: TechNode | None = None) -> np.ndarray:
        """[o] EDAP vector (scalar CacheDesign.edap operation order)."""
        n, m, c = self._nmc(mem, capacity_bytes, node)
        e = 0.5 * (self.read_energy_j[n, m, c] + self.write_energy_j[n, m, c])
        d = 0.5 * (self.read_latency_s[n, m, c]
                   + self.write_latency_s[n, m, c])
        return e * d * self.area_mm2[n, m, c]

    @functools.cached_property
    def _tuned_memo(self) -> dict[tuple[int, str, int], int]:
        # per-instance winner cache: every consumer (isocap/isoarea/scaling/
        # dtco/benchmarks) re-queries the same few (node, mem, capacity)
        return {}

    def tuned_index(self, mem: str, capacity_bytes: int,
                    node: TechNode | None = None) -> int:
        """Algorithm 1: masked argmin per (target, access) -> min-EDAP nominee.

        Matches tuner's scalar loop exactly: the OPT_TARGETS metric order,
        the ACCESS_TYPES pool order, first-occurrence argmin within each
        pool, and strict-< EDAP tie-breaking across nominees.  Memoized per
        (node, mem, capacity) on the table instance.
        """
        n, m, c = self._nmc(mem, capacity_bytes, node)
        memo = self._tuned_memo
        if (n, mem, capacity_bytes) in memo:
            return memo[n, mem, capacity_bytes]
        if not self.valid[c].any():
            raise ValueError(
                f"empty design space at {capacity_bytes} bytes")
        rl = self.read_latency_s[n, m, c]
        wl = self.write_latency_s[n, m, c]
        re_ = self.read_energy_j[n, m, c]
        we_ = self.write_energy_j[n, m, c]
        flat = np.full(N_ORGS, self.area_mm2[n, m, c])
        leak = np.full(N_ORGS, self.leakage_w[n, m, c])
        metrics = (rl, wl, re_, we_, rl * re_, wl * we_, flat, leak)
        edap = self.edap(mem, capacity_bytes, node)
        best = -1
        for metric in metrics:
            for a in range(len(ACCESS_TYPES)):
                pool = self.valid[c] & (ORG_ACCESS == a)
                if not pool.any():
                    continue
                nominee = int(np.argmin(np.where(pool, metric, np.inf)))
                if best < 0 or edap[nominee] < edap[best]:
                    best = nominee
        memo[n, mem, capacity_bytes] = best
        return best

    def tuned(self, mem: str, capacity_bytes: int,
              node: TechNode | None = None) -> CacheDesign:
        return self.design(mem, capacity_bytes,
                           self.tuned_index(mem, capacity_bytes, node),
                           node=node)

    # -- vectorized feasibility (iso-area) ---------------------------------

    def areas(self, mem: str, node: TechNode | None = None) -> np.ndarray:
        """[c] area vector — org-independent, so no tuning required."""
        return self.area_mm2[self._node_index(node), self.mems.index(mem)]

    # -- per-chunk slicing (sharded sweeps) --------------------------------

    def subset(self, mems: tuple[str, ...] | None = None,
               capacities_bytes: tuple[int, ...] | None = None,
               nodes: tuple[TechNode, ...] | None = None) -> DesignTable:
        """Slice a sub-table along the node/mem/capacity axes without
        re-evaluating the circuit sweep — the per-chunk design table of a
        sharded mega-sweep.  Algorithm-1 winners already memoized on this
        table are carried over (remapped to the child's node indices), so
        chunk lowering never re-runs a tuning the full table has done.
        """
        nodes = tuple(nodes) if nodes is not None else self.nodes
        mems = tuple(mems) if mems is not None else self.mems
        caps = tuple(int(c) for c in capacities_bytes) \
            if capacities_bytes is not None else self.capacities_bytes
        try:
            ni = [self.nodes.index(nd) for nd in nodes]
            mi = [self.mems.index(m) for m in mems]
            ci = [self.capacities_bytes.index(c) for c in caps]
        except ValueError as e:
            raise ValueError(f"subset axis not in table: {e}") from None
        sel3 = np.ix_(ni, mi, ci)
        child = DesignTable(
            nodes=nodes, mems=mems, capacities_bytes=caps,
            read_latency_s=self.read_latency_s[sel3],
            write_latency_s=self.write_latency_s[sel3],
            read_energy_j=self.read_energy_j[sel3],
            write_energy_j=self.write_energy_j[sel3],
            leakage_w=self.leakage_w[sel3],
            area_mm2=self.area_mm2[sel3],
            valid=self.valid[ci],
        )
        # carry over Algorithm-1 winners (org indices are axis-invariant:
        # the org grid and the per-capacity valid mask are shared)
        node_remap = {old: new for new, old in enumerate(ni)}
        child._tuned_memo.update(
            {(node_remap[n], mem, cap): org
             for (n, mem, cap), org in self._tuned_memo.items()
             if n in node_remap and mem in mems and cap in caps})
        return child


def _as_nodes(nodes) -> tuple[TechNode, ...]:
    """Normalize a single TechNode or a sequence of them to a tuple."""
    return (nodes,) if isinstance(nodes, TechNode) else tuple(nodes)


def _per_node(seq, n_nodes: int, what: str):
    """Normalize explicit cells/cals to a per-node nested tuple: a flat
    per-mem sequence is accepted for single-node sweeps (the tuner and the
    calibration fixed point pass trial values that way)."""
    seq = tuple(seq)
    if seq and not isinstance(seq[0], (tuple, list)):
        seq = (seq,)
    if len(seq) != n_nodes:
        raise ValueError(f"{what} must be given per node "
                         f"({len(seq)} rows for {n_nodes} nodes)")
    return tuple(tuple(row) for row in seq)


def _tech_matrices(mems, cells, cals, nodes):
    if cells is None:
        cells = tuple(tuple(bitcell_mod.characterize(m, nd) for m in mems)
                      for nd in nodes)
    else:
        cells = _per_node(cells, len(nodes), "cells")
    if cals is None:
        from repro.core import calibration  # deferred: get() calls back here
        cals = tuple(tuple(calibration.get(m, nd) for m in mems)
                     for nd in nodes)
    else:
        cals = _per_node(cals, len(nodes), "cals")
    cell_mat = np.stack([np.stack([c.as_array() for c in row])
                         for row in cells])
    cal_mat = np.array([[[getattr(cal, f) for f in CAL_FIELDS]
                         for cal in row] for row in cals], dtype=np.float64)
    is_sram = np.array([m == "sram" for m in mems])
    node_mat = np.stack([node_row(nd) for nd in nodes])
    return cell_mat, cal_mat, is_sram, node_mat


def _run_kernel(cell_mat, cal_mat, is_sram, node_mat, caps_arr,
                banks, rows, cols, acc) -> dict[str, np.ndarray]:
    """Dispatch node rows by periphery value and merge the kernel outputs.

    Rows whose periphery equals the 16 nm anchor's go through the
    anchor_peri trace (trace-time periphery constants — the bit-identity
    invariant of the refactor), every other row through the runtime-peri
    trace.  Each row is evaluated exactly once; the merge restores the
    caller's node order.  Both traces are compiled once, so a new node
    value never triggers a recompile.
    """
    node4 = np.ascontiguousarray(node_mat[:, :_N_TECHNODE])
    peri = np.ascontiguousarray(node_mat[:, _N_TECHNODE:])
    anchor_row = np.array([np.array_equal(p, _PERI_16NM_ROW) for p in peri])

    def run(sel, anchor_peri):
        with enable_x64():
            out = _ppa_kernel(cell_mat[sel], cal_mat[sel], is_sram,
                              node4[sel], peri[sel], caps_arr,
                              banks, rows, cols, acc,
                              anchor_peri=anchor_peri)
        return {k: np.asarray(v) for k, v in out.items()}

    if anchor_row.all():
        return run(slice(None), True)
    if not anchor_row.any():
        return run(slice(None), False)
    out_a = run(anchor_row, True)
    out_r = run(~anchor_row, False)
    merged = {}
    for k in out_a:
        full_shape = (len(anchor_row),) + out_a[k].shape[1:]
        buf = np.empty(full_shape, dtype=out_a[k].dtype)
        buf[anchor_row] = out_a[k]
        buf[~anchor_row] = out_r[k]
        merged[k] = buf
    return merged


def evaluate(capacities_bytes, orgs, mems=MEMS, cells=None, cals=None,
             nodes: TechNode | tuple[TechNode, ...] = TECH_16NM,
             ) -> dict[str, np.ndarray]:
    """Raw batched evaluation over an arbitrary organization list.

    Returns the PPA tensors keyed like CacheDesign fields: [n, m, c, o] for
    the org-dependent quantities, [n, m, c] for leakage/area.  ``orgs`` may
    be any sequence of CacheOrg (not just the standard grid) — this is what
    makes the scalar ``CacheModel.evaluate`` a one-element batch.
    """
    nodes = _as_nodes(nodes)
    mems = tuple(mems)
    caps_arr = np.array([int(c) for c in capacities_bytes], dtype=np.int64)
    banks = np.array([o.banks for o in orgs], dtype=np.int64)
    rows = np.array([o.rows for o in orgs], dtype=np.int64)
    cols = np.array([o.cols for o in orgs], dtype=np.int64)
    acc = np.array([ACCESS_TYPES.index(o.access) for o in orgs],
                   dtype=np.int64)
    cell_mat, cal_mat, is_sram, node_mat = _tech_matrices(
        mems, cells, cals, nodes)
    return _run_kernel(cell_mat, cal_mat, is_sram, node_mat, caps_arr,
                       banks, rows, cols, acc)


def sweep(capacities_bytes, mems=MEMS, cells=None, cals=None,
          nodes: TechNode | tuple[TechNode, ...] = TECH_16NM) -> DesignTable:
    """Evaluate the full (nodes x mems x capacities x orgs) cross product.

    ``cells``/``cals`` default to the characterized bitcell and fitted
    calibration per (node, technology); the calibration fixed point passes
    trial values explicitly (which is why this function must not call
    calibration.get itself).
    """
    nodes = _as_nodes(nodes)
    mems = tuple(mems)
    caps = tuple(int(c) for c in capacities_bytes)
    cell_mat, cal_mat, is_sram, node_mat = _tech_matrices(
        mems, cells, cals, nodes)
    caps_arr = np.array(caps, dtype=np.int64)
    out = _run_kernel(cell_mat, cal_mat, is_sram, node_mat, caps_arr,
                      ORG_BANKS, ORG_ROWS, ORG_COLS, ORG_ACCESS)
    return DesignTable(
        nodes=nodes,
        mems=mems,
        capacities_bytes=caps,
        read_latency_s=np.asarray(out["read_latency_s"]),
        write_latency_s=np.asarray(out["write_latency_s"]),
        read_energy_j=np.asarray(out["read_energy_j"]),
        write_energy_j=np.asarray(out["write_energy_j"]),
        leakage_w=np.asarray(out["leakage_w"]),
        area_mm2=np.asarray(out["area_mm2"]),
        valid=valid_mask(caps_arr),
    )


@functools.lru_cache(maxsize=None)
def _design_table_cached(nodes: tuple[TechNode, ...],
                         mems: tuple[str, ...],
                         capacities_bytes: tuple[int, ...]) -> DesignTable:
    return sweep(capacities_bytes, mems=mems, nodes=nodes)


def design_table(mems: tuple[str, ...],
                 capacities_bytes: tuple[int, ...],
                 nodes: TechNode | tuple[TechNode, ...] = TECH_16NM,
                 ) -> DesignTable:
    """Memoized fully-calibrated table — the shared sweep every consumer
    (tuner, isocap, isoarea, scaling, dtco, benchmarks) reads from.

    The memo key is (nodes, mems, capacities): a non-default node gets its
    own table (it used to silently share the 16 nm entry — the memo-key
    bug this signature fixes)."""
    return _design_table_cached(_as_nodes(nodes), tuple(mems),
                                tuple(int(c) for c in capacities_bytes))


design_table.cache_clear = _design_table_cached.cache_clear
design_table.cache_info = _design_table_cached.cache_info


def warmup(cap_counts: tuple[int, ...] = (1, 2, 4),
           nodes: TechNode | tuple[TechNode, ...] = TECH_16NM,
           mems: tuple[str, ...] = MEMS) -> int:
    """Pre-trace the batched PPA kernel at the capacity-count buckets the
    bucketed sweep path uses, and prime the layers in front of it (bitcell
    characterization, the calibration fixed point, the periphery bundle).

    The kernel specializes only on axis *counts* — capacities are runtime
    tensor inputs — so compiling one dummy table per count makes any later
    real ``design_table`` call with the same (node-count, mem-count,
    cap-count) shape a ~ms dispatch instead of a ~0.5 s trace.  The dummy
    tables land in the ``design_table`` memo under capacities no real
    sweep uses (1 MB + small offsets); they are never tuned, so the
    Algorithm-1 memo stays untouched.  Returns the number of tables
    built.  Warming non-anchor nodes additionally compiles the
    runtime-periphery trace (the anchor trace alone serves 16 nm specs).
    """
    nodes = _as_nodes(nodes)
    mems = tuple(mems)
    for count in cap_counts:
        caps = tuple((1 << 20) + 64 * i for i in range(count))
        design_table(mems, caps, nodes=nodes)
    return len(cap_counts)

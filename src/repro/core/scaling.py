"""Scalability analysis — paper §IV-C (Figs. 9, 10).

Each memory technology is EDAP-tuned independently at every capacity
(Algorithm 1), then folded through the workload model to produce mean
normalized energy / latency / EDP vs SRAM across all workloads — the
paper's projection for the GPU L2 growth trend of Fig. 1 (and, in our
hardware adaptation, for TPU-class on-chip buffer capacities).

Both sweeps are thin adapters over the unified sweep pipeline
(core/sweep.py): ppa_sweep reads tuned designs from the shared memoized
design table the spec lowers to, and workload_sweep declares a SweepSpec
whose design axis is the full (capacity x memory) grid — one circuit
evaluation plus one batched workload fold, no scalar per-combination
calls and no per-analysis fold plumbing.
"""

from __future__ import annotations

import dataclasses
import statistics
from collections.abc import Sequence

from repro.core import engine, sweep
from repro.core.isocap import INFER_BATCH, TRAIN_BATCH, MEMS
from repro.core.tech import Platform, GTX_1080TI
from repro.core.workloads import Workload, paper_workloads

CAPACITIES_MB = (1, 2, 4, 8, 16, 32)  # paper Algorithm 1's capacity set


def tuned_table(capacities_mb: Sequence[float]) -> engine.DesignTable:
    """The shared batched sweep for all technologies at these capacities."""
    return sweep.lower_designs(sweep.design_grid(MEMS, capacities_mb))[0]


@dataclasses.dataclass(frozen=True)
class PPARow:
    """Fig. 9: raw PPA of the tuned design at one capacity."""

    capacity_mb: float
    mem: str
    read_latency_ns: float
    write_latency_ns: float
    read_energy_nj: float
    write_energy_nj: float
    leakage_w: float
    area_mm2: float


@dataclasses.dataclass(frozen=True)
class ScalingRow:
    """Fig. 10: workload-mean normalized metrics at one capacity."""

    capacity_mb: float
    mem: str
    training: bool
    energy_x: float      # mean E_mem / E_sram   (lower is better)
    latency_x: float
    edp_x: float
    energy_std: float
    edp_std: float


def ppa_sweep(capacities_mb: Sequence[float] = CAPACITIES_MB) -> list[PPARow]:
    table = tuned_table(capacities_mb)
    rows = []
    for cap in capacities_mb:
        for mem in MEMS:
            d = table.tuned(mem, int(cap * 2**20))
            rows.append(PPARow(
                capacity_mb=cap, mem=mem,
                read_latency_ns=d.read_latency_s * 1e9,
                write_latency_ns=d.write_latency_s * 1e9,
                read_energy_nj=d.read_energy_j * 1e9,
                write_energy_nj=d.write_energy_j * 1e9,
                leakage_w=d.leakage_w,
                area_mm2=d.area_mm2,
            ))
    return rows


def workload_sweep(capacities_mb: Sequence[float] = CAPACITIES_MB,
                   workloads: dict[str, Workload] | None = None,
                   platform: Platform = GTX_1080TI) -> list[ScalingRow]:
    """One declarative sweep over the [workload x stage] x [memory x
    capacity] grid, then per-(capacity, stage, memory) reductions over the
    result tensors."""
    workloads = workloads if workloads is not None else paper_workloads()
    stages = ((False, INFER_BATCH), (True, TRAIN_BATCH))
    spec = sweep.SweepSpec(
        name="scaling",
        scenarios=sweep.workload_scenarios(workloads, stages,
                                           stage_major=True),
        designs=sweep.design_grid(MEMS, capacities_mb),
        platforms=(platform,))
    wt = sweep.run(spec).tables[0]

    energy = wt.total_j(False)   # [s, d]
    latency = wt.runtime_s
    edp = wt.edp(True)
    n_wl = len(workloads)
    rows = []
    for ci, cap in enumerate(capacities_mb):
        d_of = {m: ci * len(MEMS) + mi for mi, m in enumerate(MEMS)}
        for si, (training, batch) in enumerate(stages):
            s_ids = slice(si * n_wl, (si + 1) * n_wl)
            for mem in ("stt", "sot"):
                m, s = d_of[mem], d_of["sram"]
                ex = (energy[s_ids, m] / energy[s_ids, s]).tolist()
                lx = (latency[s_ids, m] / latency[s_ids, s]).tolist()
                ed = (edp[s_ids, m] / edp[s_ids, s]).tolist()
                rows.append(ScalingRow(
                    capacity_mb=cap, mem=mem, training=training,
                    energy_x=statistics.mean(ex),
                    latency_x=statistics.mean(lx),
                    edp_x=statistics.mean(ed),
                    energy_std=statistics.pstdev(ex),
                    edp_std=statistics.pstdev(ed),
                ))
    return rows


def headline(rows: list[ScalingRow]) -> dict[str, dict[str, float]]:
    """Paper §IV-C claims: max reductions across the capacity sweep."""
    out = {}
    for mem in ("stt", "sot"):
        sub = [r for r in rows if r.mem == mem]
        out[mem] = dict(
            energy_reduction_max=max(1 / r.energy_x for r in sub),
            latency_reduction_max=max(1 / r.latency_x for r in sub),
            edp_reduction_max=max(1 / r.edp_x for r in sub),
        )
    return out

"""Workload descriptors — paper Table III CNNs + LM workload adapter.

The architecture-level analyses need, per workload, the layer-by-layer
tensor dimensions from which the traffic model (core/traffic.py) derives L2
read/write transactions, DRAM reuse behavior, and compute time.  The paper
profiles Caffe on a 1080 Ti; we reconstruct the same quantities from the
published layer configurations (the Caffe execution model is encoded in
traffic.py: conv layers loop images with a shared im2col buffer, fc layers
run one batched GEMM).

The five CNNs reproduce paper Table III within a few percent (validated in
tests/test_workloads.py).  `lm_workload` adapts an assigned LM architecture
config into the same representation, which is how the DeepNVM++ pipeline is
applied to the JAX framework's own workloads (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import functools

DTYPE_BYTES = 4  # Caffe fp32


@dataclasses.dataclass(frozen=True)
class Layer:
    """One macro layer: convolution or fully-connected (GEMM)."""

    name: str
    kind: str          # "conv" | "fc"
    cin: int
    cout: int
    k: int             # kernel size (1 for fc)
    hout: int          # output spatial (1 for fc)
    wout: int
    hin: int
    win: int
    groups: int = 1

    @property
    def macs(self) -> int:
        return self.cout * (self.cin // self.groups) * self.k * self.k \
            * self.hout * self.wout

    @property
    def params(self) -> int:
        return self.cout * (self.cin // self.groups) * self.k * self.k

    @property
    def weight_bytes(self) -> int:
        return self.params * DTYPE_BYTES

    @property
    def act_in_bytes(self) -> int:
        return self.cin * self.hin * self.win * DTYPE_BYTES

    @property
    def act_out_bytes(self) -> int:
        return self.cout * self.hout * self.wout * DTYPE_BYTES

    @property
    def im2col_bytes(self) -> int:
        """Caffe's unfolded input buffer (conv only; 1x1 convs skip it)."""
        if self.kind != "conv" or self.k == 1:
            return 0
        return self.cin * self.k * self.k * self.hout * self.wout * DTYPE_BYTES


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    layers: tuple[Layer, ...]
    top5_error: float = 0.0

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_params(self) -> int:
        return sum(l.params for l in self.layers)

    @property
    def conv_layers(self) -> int:
        return sum(1 for l in self.layers if l.kind == "conv")

    @property
    def fc_layers(self) -> int:
        return sum(1 for l in self.layers if l.kind == "fc")


def _conv(name, cin, cout, k, hin, stride=1, groups=1, pad=None, win=None):
    win = hin if win is None else win
    pad = k // 2 if pad is None else pad
    hout = (hin + 2 * pad - k) // stride + 1
    wout = (win + 2 * pad - k) // stride + 1
    return Layer(name, "conv", cin, cout, k, hout, wout, hin, win, groups)


def _fc(name, cin, cout):
    return Layer(name, "fc", cin, cout, 1, 1, 1, 1, 1)


# ---------------------------------------------------------------------------
# Table III networks
# ---------------------------------------------------------------------------


def alexnet() -> Workload:
    ls = [
        _conv("conv1", 3, 96, 11, 227, stride=4, pad=0),   # 55x55
        _conv("conv2", 96, 256, 5, 27, groups=2),          # 27x27 (post-pool)
        _conv("conv3", 256, 384, 3, 13),
        _conv("conv4", 384, 384, 3, 13, groups=2),
        _conv("conv5", 384, 256, 3, 13, groups=2),
        _fc("fc6", 9216, 4096),
        _fc("fc7", 4096, 4096),
        _fc("fc8", 4096, 1000),
    ]
    return Workload("alexnet", tuple(ls), top5_error=16.4)


def vgg16() -> Workload:
    ls, h, cin = [], 224, 3
    for i, (cout, reps) in enumerate([(64, 2), (128, 2), (256, 3),
                                      (512, 3), (512, 3)]):
        for r in range(reps):
            ls.append(_conv(f"conv{i + 1}_{r + 1}", cin, cout, 3, h))
            cin = cout
        h //= 2
    ls += [_fc("fc6", 25088, 4096), _fc("fc7", 4096, 4096),
           _fc("fc8", 4096, 1000)]
    return Workload("vgg16", tuple(ls), top5_error=7.3)


def resnet18() -> Workload:
    ls = [_conv("conv1", 3, 64, 7, 224, stride=2)]  # 112x112 (pool -> 56)
    h, cin = 56, 64
    for stage, cout in enumerate([64, 128, 256, 512]):
        for block in range(2):
            stride = 2 if (stage > 0 and block == 0) else 1
            hout = h // stride
            ls.append(_conv(f"s{stage}b{block}c1", cin, cout, 3, h, stride=stride))
            ls.append(_conv(f"s{stage}b{block}c2", cout, cout, 3, hout))
            if stride == 2 or cin != cout:
                ls.append(_conv(f"s{stage}b{block}ds", cin, cout, 1, h,
                                stride=stride, pad=0))
            cin, h = cout, hout
    ls.append(_fc("fc", 512, 1000))
    return Workload("resnet18", tuple(ls), top5_error=10.71)


def squeezenet() -> Workload:
    # SqueezeNet v1.0: conv1 + 8 fire modules (3 convs each) + conv10 = 26.
    def fire(name, cin, s1, e1, e3, h):
        return [
            _conv(f"{name}.s1", cin, s1, 1, h, pad=0),
            _conv(f"{name}.e1", s1, e1, 1, h, pad=0),
            _conv(f"{name}.e3", s1, e3, 3, h),
        ]

    ls = [_conv("conv1", 3, 96, 7, 224, stride=2, pad=0)]  # 109 -> pool 54
    ls += fire("fire2", 96, 16, 64, 64, 54)
    ls += fire("fire3", 128, 16, 64, 64, 54)
    ls += fire("fire4", 128, 32, 128, 128, 54)
    ls += fire("fire5", 256, 32, 128, 128, 27)   # post-pool
    ls += fire("fire6", 256, 48, 192, 192, 27)
    ls += fire("fire7", 384, 48, 192, 192, 27)
    ls += fire("fire8", 384, 64, 256, 256, 27)
    ls += fire("fire9", 512, 64, 256, 256, 13)   # post-pool
    ls.append(_conv("conv10", 512, 1000, 1, 13, pad=0))
    return Workload("squeezenet", tuple(ls), top5_error=16.4)


def googlenet() -> Workload:
    # Inception v1 (57 convs, 1 fc).
    def inception(name, cin, n1, r3, n3, r5, n5, pp, h):
        return [
            _conv(f"{name}.1x1", cin, n1, 1, h, pad=0),
            _conv(f"{name}.3r", cin, r3, 1, h, pad=0),
            _conv(f"{name}.3x3", r3, n3, 3, h),
            _conv(f"{name}.5r", cin, r5, 1, h, pad=0),
            _conv(f"{name}.5x5", r5, n5, 5, h),
            _conv(f"{name}.pp", cin, pp, 1, h, pad=0),
        ]

    ls = [
        _conv("conv1", 3, 64, 7, 224, stride=2),      # 112
        _conv("conv2r", 64, 64, 1, 56, pad=0),        # post-pool
        _conv("conv2", 64, 192, 3, 56),
    ]
    ls += inception("3a", 192, 64, 96, 128, 16, 32, 32, 28)
    ls += inception("3b", 256, 128, 128, 192, 32, 96, 64, 28)
    ls += inception("4a", 480, 192, 96, 208, 16, 48, 64, 14)
    ls += inception("4b", 512, 160, 112, 224, 24, 64, 64, 14)
    ls += inception("4c", 512, 128, 128, 256, 24, 64, 64, 14)
    ls += inception("4d", 512, 112, 144, 288, 32, 64, 64, 14)
    ls += inception("4e", 528, 256, 160, 320, 32, 128, 128, 14)
    ls += inception("5a", 832, 256, 160, 320, 32, 128, 128, 7)
    ls += inception("5b", 832, 384, 192, 384, 48, 128, 128, 7)
    ls.append(_fc("fc", 1024, 1000))
    return Workload("googlenet", tuple(ls), top5_error=6.7)


def paper_workloads() -> dict[str, Workload]:
    """The five DNNs of paper Table III, in figure order."""
    return {w.name: w for w in
            (alexnet(), googlenet(), vgg16(), resnet18(), squeezenet())}


@functools.lru_cache(maxsize=None)
def registry() -> dict[str, Workload]:
    """The CNN side of the unified scenario namespace ("cnn/<name>/...",
    repro.scenarios): every named workload the traffic model knows.
    Currently the paper Table III networks; new entries extend the
    symbolic-spec vocabulary without touching the resolver."""
    return paper_workloads()


def get(name: str) -> Workload:
    """Resolve a workload by registry name (symbolic-spec resolution)."""
    try:
        return registry()[name]
    except KeyError:
        raise ValueError(f"unknown workload {name!r}; available: "
                         f"{sorted(registry())}") from None


# Reference values from paper Table III for validation.
TABLE3 = {
    "alexnet": dict(macs=724e6, params=61e6, conv=5, fc=3),
    "googlenet": dict(macs=1.43e9, params=7e6, conv=57, fc=1),
    "vgg16": dict(macs=15.5e9, params=138e6, conv=13, fc=3),
    "resnet18": dict(macs=2e9, params=11.8e6, conv=17, fc=1),
    "squeezenet": dict(macs=837e6, params=1.2e6, conv=26, fc=0),
}


# ---------------------------------------------------------------------------
# LM workload adapter (framework tie-in; beyond-paper)
# ---------------------------------------------------------------------------


def lm_workload(name: str, *, n_layers: int, d_model: int, d_ff: int,
                n_heads: int, n_kv_heads: int, head_dim: int, vocab: int,
                seq_len: int, n_experts: int = 0, top_k: int = 0,
                d_expert: int = 0, dtype_bytes: int = 2) -> Workload:
    """Represent one transformer layer stack as GEMM (fc) macro-layers per
    token batch, so the same traffic pipeline applies to LM workloads.

    Each attention/MLP projection becomes an fc layer with the token batch
    folded into the caller's `batch` argument of the traffic model; MoE
    layers contribute their active experts (6*N_active*D compute model).
    """
    del dtype_bytes  # L2 traffic model fixes fp32; LMs rescale via bytes
    q_dim = n_heads * head_dim
    kv_dim = n_kv_heads * head_dim
    ls: list[Layer] = []
    for i in range(n_layers):
        ls += [
            _fc(f"l{i}.q", d_model, q_dim),
            _fc(f"l{i}.k", d_model, kv_dim),
            _fc(f"l{i}.v", d_model, kv_dim),
            _fc(f"l{i}.o", q_dim, d_model),
        ]
        if n_experts:
            for e in range(top_k):
                ls += [_fc(f"l{i}.e{e}.up", d_model, 2 * d_expert),
                       _fc(f"l{i}.e{e}.down", d_expert, d_model)]
        else:
            ls += [_fc(f"l{i}.up", d_model, 2 * d_ff),
                   _fc(f"l{i}.down", d_ff, d_model)]
    ls.append(_fc("lm_head", d_model, vocab))
    # attention score/context GEMMs: seq-dependent, modeled as one fc whose
    # "weights" are the KV cache of one sequence
    ls.append(_fc("attn_sdpa", seq_len * 2, n_layers * kv_dim))
    return Workload(f"lm:{name}", tuple(ls))

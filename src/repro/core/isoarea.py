"""Iso-area analysis — paper §III-D / §IV-B (Figs. 6, 7, 8).

The NVM density advantage is spent on capacity: the MRAM cache that fits
the 3 MB SRAM area budget (7 MB STT / 10 MB SOT, from the tuner's area
model).  The larger capacity reduces DRAM traffic (Fig. 6 — GPGPU-Sim in
the paper, the reuse-distance model here), which is where iso-area MRAM
wins: slower, bigger caches, but far fewer costly off-chip accesses.

Figs. 6-8 are read from batched workload-engine folds: the DRAM curve is
one [workload] x [capacity] miss-curve evaluation and the energy/EDP rows
one [workload-stage] x [memory] evaluation against the iso-area designs.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

from repro.core import engine, tuner, workload_engine
from repro.core.isocap import (IsoCapRow, INFER_BATCH, TRAIN_BATCH,
                               _stage_rows)
from repro.core.tech import Platform, GTX_1080TI
from repro.core.workloads import Workload, paper_workloads, alexnet


@dataclasses.dataclass(frozen=True)
class IsoAreaDesigns:
    sram: object
    stt: object
    sot: object
    stt_capacity_mb: int
    sot_capacity_mb: int

    def as_dict(self):
        return {"sram": self.sram, "stt": self.stt, "sot": self.sot}


def designs(sram_capacity_mb: float = 3.0) -> IsoAreaDesigns:
    """Iso-area design set, read from one shared batched sweep over the
    three (technology, capacity) corners the area budget selects."""
    stt_mb = tuner.iso_area_capacity("stt", sram_capacity_mb)
    sot_mb = tuner.iso_area_capacity("sot", sram_capacity_mb)
    caps = (int(sram_capacity_mb * 2**20), stt_mb * 2**20, sot_mb * 2**20)
    table = engine.design_table(("sram", "stt", "sot"), caps)
    return IsoAreaDesigns(
        sram=table.tuned("sram", caps[0]),
        stt=table.tuned("stt", caps[1]),
        sot=table.tuned("sot", caps[2]),
        stt_capacity_mb=stt_mb,
        sot_capacity_mb=sot_mb,
    )


def dram_reduction_curve(workload: Workload | None = None, batch: int = INFER_BATCH,
                         training: bool = False,
                         capacities_mb: Sequence[float] = (3, 6, 7, 10, 12, 24),
                         ) -> dict[float, float]:
    """Fig. 6: % reduction in DRAM accesses vs the 3 MB baseline as the
    last-level cache grows (paper: AlexNet via GPGPU-Sim/DarkNet)."""
    w = workload if workload is not None else alexnet()
    stats = workload_engine.stats_for(w, batch, training)
    caps = (3,) + tuple(capacities_mb)
    tx = workload_engine.dram_tx([stats], [c * 2**20 for c in caps])[0]
    return {c: 100.0 * (1.0 - float(tx[1 + i] / tx[0]))
            for i, c in enumerate(capacities_mb)}


def analyze(workloads: dict[str, Workload] | None = None,
            platform: Platform = GTX_1080TI,
            infer_batch: int = INFER_BATCH,
            train_batch: int = TRAIN_BATCH) -> list[IsoCapRow]:
    """Figs. 7/8: energy and EDP at iso-area (with/without DRAM terms) —
    one batched [workload-stage] x [memory] fold at the iso-area corners."""
    workloads = workloads if workloads is not None else paper_workloads()
    return _stage_rows(workloads, designs().as_dict(), platform,
                       infer_batch, train_batch)


def summary(rows: list[IsoCapRow]) -> dict[str, dict[str, float]]:
    out: dict[str, dict[str, float]] = {}
    n = len(rows)
    for mem in ("stt", "sot"):
        out[mem] = dict(
            dyn_energy_x=sum(r.norm("dyn", mem) for r in rows) / n,
            leak_reduction=sum(1 / r.norm("leak", mem) for r in rows) / n,
            energy_reduction=sum(1 / r.norm("energy", mem) for r in rows) / n,
            edp_reduction_no_dram=sum(1 / r.norm("edp", mem, False)
                                      for r in rows) / n,
            edp_reduction_with_dram=sum(1 / r.norm("edp", mem, True)
                                        for r in rows) / n,
        )
    return out

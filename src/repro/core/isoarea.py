"""Iso-area analysis — paper §III-D / §IV-B (Figs. 6, 7, 8).

The NVM density advantage is spent on capacity: the MRAM cache that fits
the 3 MB SRAM area budget (7 MB STT / 10 MB SOT, from the tuner's area
model).  The larger capacity reduces DRAM traffic (Fig. 6 — GPGPU-Sim in
the paper, the reuse-distance model here), which is where iso-area MRAM
wins: slower, bigger caches, but far fewer costly off-chip accesses.

Both Fig. 6 and Figs. 7/8 are thin SweepSpec adapters (core/sweep.py):
the DRAM curve reads the platform-independent [scenario] x [capacity]
DRAM-transaction tensor of a capacity-axis sweep, and the energy/EDP rows
come from a sweep over the iso-area design corners.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

from repro.core import sweep, tuner
from repro.core.isocap import (INFER_BATCH, TRAIN_BATCH, IsoCapRow,
                               rows_from_result)
from repro.core.tech import Platform, GTX_1080TI, TechNode, TECH_16NM
from repro.core.workloads import Workload, paper_workloads, alexnet


@dataclasses.dataclass(frozen=True)
class IsoAreaDesigns:
    sram: object
    stt: object
    sot: object
    stt_capacity_mb: int
    sot_capacity_mb: int

    def as_dict(self):
        return {"sram": self.sram, "stt": self.stt, "sot": self.sot}


def corners(sram_capacity_mb: float = 3.0,
            node: TechNode = TECH_16NM) -> tuple[sweep.DesignPoint, ...]:
    """The iso-area design corners the area budget selects: SRAM at its
    own capacity, each MRAM flavor at the largest capacity fitting the
    SRAM area (one normalization group — the SRAM baseline).  ``node``
    runs the whole selection at another technology node: the area budget
    (and so the MRAM capacities) is re-derived from that node's designs —
    the per-node iso-area study."""
    return sweep.design_corners(
        (("sram", sram_capacity_mb),
         ("stt", tuner.iso_area_capacity("stt", sram_capacity_mb,
                                         node=node)),
         ("sot", tuner.iso_area_capacity("sot", sram_capacity_mb,
                                         node=node))),
        nodes=(node,))


def designs(sram_capacity_mb: float = 3.0,
            node: TechNode = TECH_16NM) -> IsoAreaDesigns:
    """Iso-area design set, read from one shared batched sweep over the
    three (technology, capacity) corners the area budget selects."""
    points = corners(sram_capacity_mb, node)
    _, (sram_d, stt_d, sot_d) = sweep.lower_designs(points)
    return IsoAreaDesigns(
        sram=sram_d, stt=stt_d, sot=sot_d,
        stt_capacity_mb=int(points[1].capacity_mb),
        sot_capacity_mb=int(points[2].capacity_mb),
    )


def dram_reduction_curve(workload: Workload | None = None, batch: int = INFER_BATCH,
                         training: bool = False,
                         capacities_mb: Sequence[float] = (3, 6, 7, 10, 12, 24),
                         ) -> dict[float, float]:
    """Fig. 6: % reduction in DRAM accesses vs the 3 MB baseline as the
    last-level cache grows (paper: AlexNet via GPGPU-Sim/DarkNet).  The
    capacity axis is the design axis of a sweep; the curve reads its
    platform-independent DRAM-transaction tensor."""
    w = workload if workload is not None else alexnet()
    caps = (3,) + tuple(capacities_mb)
    spec = sweep.SweepSpec(
        name="isoarea-dram",
        scenarios=sweep.workload_scenarios((w,), ((training, batch),)),
        designs=tuple(sweep.DesignPoint("sram", int(c * 2**20), group=i)
                      for i, c in enumerate(caps)))
    tx = sweep.run(spec).dram_tx[0]
    return {c: 100.0 * (1.0 - float(tx[1 + i] / tx[0]))
            for i, c in enumerate(capacities_mb)}


def analyze(workloads: dict[str, Workload] | None = None,
            platform: Platform = GTX_1080TI,
            infer_batch: int = INFER_BATCH,
            train_batch: int = TRAIN_BATCH,
            node: TechNode = TECH_16NM) -> list[IsoCapRow]:
    """Figs. 7/8: energy and EDP at iso-area (with/without DRAM terms) —
    one declarative sweep at the iso-area corners (of ``node``, for the
    per-node iso-area study)."""
    workloads = workloads if workloads is not None else paper_workloads()
    spec = sweep.SweepSpec(
        name="isoarea" if node == TECH_16NM else f"isoarea@{node.name}",
        scenarios=sweep.workload_scenarios(
            workloads, ((False, infer_batch), (True, train_batch))),
        designs=corners(node=node),
        platforms=(platform,))
    return rows_from_result(sweep.run(spec))


def summary(rows: list[IsoCapRow]) -> dict[str, dict[str, float]]:
    out: dict[str, dict[str, float]] = {}
    n = len(rows)
    for mem in ("stt", "sot"):
        out[mem] = dict(
            dyn_energy_x=sum(r.norm("dyn", mem) for r in rows) / n,
            leak_reduction=sum(1 / r.norm("leak", mem) for r in rows) / n,
            energy_reduction=sum(1 / r.norm("energy", mem) for r in rows) / n,
            edp_reduction_no_dram=sum(1 / r.norm("edp", mem, False)
                                      for r in rows) / n,
            edp_reduction_with_dram=sum(1 / r.norm("edp", mem, True)
                                        for r in rows) / n,
        )
    return out

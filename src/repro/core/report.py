"""CSV/markdown emission helpers shared by benchmarks and launch tools."""

from __future__ import annotations

import csv
import io
import os
from collections.abc import Iterable, Mapping, Sequence


def csv_str(rows: Sequence[Mapping[str, object]],
            fields: Sequence[str] | None = None) -> str:
    if not rows:
        return ""
    fields = list(fields) if fields else list(rows[0].keys())
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=fields, extrasaction="ignore")
    w.writeheader()
    for r in rows:
        w.writerow({k: _fmt(r.get(k)) for k in fields})
    return buf.getvalue()


def write_csv(path: str, rows: Sequence[Mapping[str, object]],
              fields: Sequence[str] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(csv_str(rows, fields))


def markdown_table(rows: Sequence[Mapping[str, object]],
                   fields: Sequence[str] | None = None) -> str:
    if not rows:
        return "(empty)"
    fields = list(fields) if fields else list(rows[0].keys())
    out = ["| " + " | ".join(fields) + " |",
           "|" + "|".join("---" for _ in fields) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(_fmt(r.get(k))) for k in fields) + " |")
    return "\n".join(out)


def _fmt(v: object) -> object:
    if isinstance(v, float):
        if v == 0:
            return 0
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.4g}"
        return round(v, 4)
    return v

"""CSV/markdown emission helpers shared by benchmarks and launch tools."""

from __future__ import annotations

import csv
import io
import os
from collections.abc import Mapping, Sequence


def csv_str(rows: Sequence[Mapping[str, object]],
            fields: Sequence[str] | None = None,
            fmt: "callable | None" = None) -> str:
    """Rows to CSV text.  ``fmt`` maps each cell value; the default
    human-readable rounding is ``_fmt``, and ``fmt_exact`` keeps floats at
    full repr precision (the CLI's bit-for-bit mode)."""
    if not rows:
        return ""
    fmt = fmt if fmt is not None else _fmt
    fields = list(fields) if fields else list(rows[0].keys())
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=fields, extrasaction="ignore")
    w.writeheader()
    for r in rows:
        w.writerow({k: fmt(r.get(k)) for k in fields})
    return buf.getvalue()


def write_csv(path: str, rows: Sequence[Mapping[str, object]],
              fields: Sequence[str] | None = None,
              fmt: "callable | None" = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(csv_str(rows, fields, fmt))


def fmt_exact(v: object) -> object:
    """Lossless cell formatting: floats via repr (round-trips exactly)."""
    return repr(v) if isinstance(v, float) else v


def markdown_table(rows: Sequence[Mapping[str, object]],
                   fields: Sequence[str] | None = None) -> str:
    if not rows:
        return "(empty)"
    fields = list(fields) if fields else list(rows[0].keys())
    out = ["| " + " | ".join(fields) + " |",
           "|" + "|".join("---" for _ in fields) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(_fmt(r.get(k))) for k in fields) + " |")
    return "\n".join(out)


def _fmt(v: object) -> object:
    if isinstance(v, float):
        if v == 0:
            return 0
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.4g}"
        return round(v, 4)
    return v

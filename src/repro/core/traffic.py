"""Workload memory-traffic and runtime model — the architecture layer.

Replaces the paper's nvprof profiling (iso-capacity) and feeds the cache
simulator (iso-area).  It encodes the Caffe execution model the paper
profiles:

  conv layers   loop over the batch with a shared im2col buffer:
                per image: write col, read col + weights (GEMM), write out.
  fc layers     one batched GEMM: read weights once per batch.
  training      forward + backward per batch: backward re-reads weights
                (dgrad), saved activations and re-built col buffers (wgrad),
                writes input grads and weight grads; the optimizer reads
                weights/momentum/grads and writes weights/momentum.

Every access is tagged with a characteristic **reuse distance** (bytes of
intervening traffic before the next use of the same data), which yields the
DRAM transaction count for any cache capacity — the quantity GPGPU-Sim
provides in the paper (Fig. 6) — without a cycle-level simulator.  An exact
trace-driven simulator (core/cachesim.py) validates the analytic model on
small traces.

The runtime model is the paper's "simple model" (§III-B): transactions x
per-transaction latency/energy, with a compute-overlap factor
(Platform.mem_serialization) since GPUs overlap memory and compute.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

from repro.core.cachemodel import LINE_BYTES, CacheDesign
from repro.core.tech import Platform, GTX_1080TI
from repro.core.workloads import Workload

INF = float("inf")

# Fraction of LLC capacity that behaves as fully-associative working space
# (conflict misses + multi-kernel interleaving under 16-way LRU);
# calibrated together with MISS_CURVE_P against the Fig. 6 anchors
# (paper: 14.6% DRAM reduction @7 MB, 19.8% @10 MB -> model 13.6%/18.5%).
ASSOC_EFFICIENCY = 0.5
# Exponent of the smooth miss-probability curve (RD/(RD+C_eff))^p.  p=2
# mimics the sharp-but-not-binary capacity transitions GPGPU-Sim shows.
MISS_CURVE_P = 2.0
# Backward-pass activation re-read multiplier (dgrad + wgrad both touch
# saved activations; Caffe also re-reads for the ReLU/pool masks).
BWD_ACT_REREADS = 2.0
# GPU compute efficiency on DL GEMMs/convs (nvprof-era Caffe on Pascal).
COMPUTE_EFFICIENCY = 0.60
# GEMM tile dims (thread-block tiles): inputs are re-read from L2 once per
# tile of the opposing dimension — the dominant source of L2 *read*
# amplification on GPUs (weights re-read per output tile, col buffer
# re-read per weight tile).  These short-distance re-reads hit in any LLC.
GEMM_TILE = 128
TILE_REUSE_RD = 256 * 1024  # reuse distance of intra-GEMM tile re-reads


@dataclasses.dataclass(frozen=True)
class AccessStream:
    """A homogeneous group of L2 accesses within one batch."""

    label: str
    bytes_total: float       # total bytes moved by this stream per batch
    is_write: bool
    reuse_distance: float    # bytes of intervening traffic until next use
                             # (INF = streaming / first touch: always misses)
    writeback: bool = True   # dirty data written back to DRAM on eviction


@dataclasses.dataclass(frozen=True)
class TrafficStats:
    """Per-batch memory statistics of one workload execution."""

    workload: str
    batch: int
    training: bool
    streams: tuple[AccessStream, ...]
    macs_per_batch: float

    # Structure-of-arrays view of the streams: the miss-curve fold runs
    # vectorized, and the per-capacity DRAM curve is memoized (the stats
    # are capacity-independent, so every cache design re-queries the same
    # few capacities).  cached_property writes the instance __dict__
    # directly, so it composes with the frozen dataclass.

    @functools.cached_property
    def _arrays(self) -> dict[str, np.ndarray]:
        return dict(
            bytes_total=np.array([s.bytes_total for s in self.streams],
                                 dtype=np.float64),
            is_write=np.array([s.is_write for s in self.streams], dtype=bool),
            reuse_distance=np.array([s.reuse_distance for s in self.streams],
                                    dtype=np.float64),
            dram_visible=np.array([not (s.is_write and not s.writeback)
                                   for s in self.streams], dtype=bool),
        )

    @functools.cached_property
    def _dram_tx_memo(self) -> dict[float, float]:
        return {}

    @functools.cached_property
    def l2_read_tx(self) -> float:
        a = self._arrays
        return float(a["bytes_total"][~a["is_write"]].sum()) / LINE_BYTES

    @functools.cached_property
    def l2_write_tx(self) -> float:
        a = self._arrays
        return float(a["bytes_total"][a["is_write"]].sum()) / LINE_BYTES

    @property
    def read_write_ratio(self) -> float:
        return self.l2_read_tx / max(1.0, self.l2_write_tx)

    def dram_tx(self, capacity_bytes: float) -> float:
        """DRAM transactions for an LLC of the given capacity.

        Each access stream misses with probability
        (RD / (RD + C_eff))^MISS_CURVE_P — a smooth capacity-miss curve
        (streaming accesses with RD=inf always miss); dirty write streams
        add write-back traffic on eviction with the same probability."""
        memo = self._dram_tx_memo
        if capacity_bytes not in memo:
            a = self._arrays
            c_eff = capacity_bytes * ASSOC_EFFICIENCY
            rd = a["reuse_distance"]
            with np.errstate(invalid="ignore"):
                miss_p = np.where(np.isinf(rd), 1.0,
                                  (rd / (rd + c_eff)) ** MISS_CURVE_P)
            tx = a["bytes_total"] / LINE_BYTES * miss_p
            memo[capacity_bytes] = float(tx[a["dram_visible"]].sum())
        return memo[capacity_bytes]


def _gemm_amp_weights(layer) -> float:
    """Times the weight matrix is re-read from L2: once per N-dim tile."""
    n = layer.hout * layer.wout if layer.kind == "conv" else 1
    return max(1.0, math.ceil(n / GEMM_TILE))


def _gemm_amp_col(layer) -> float:
    """Times the col/activation matrix is re-read: once per M-dim tile."""
    return max(1.0, math.ceil(layer.cout / GEMM_TILE))


def _conv_streams(layer, batch: int) -> list[AccessStream]:
    """Caffe/DarkNet conv: per image — im2col write/read + tiled GEMM."""
    b = float(batch)
    col = layer.im2col_bytes
    per_image_ws = col + layer.act_in_bytes + layer.act_out_bytes \
        + layer.weight_bytes
    amp_w = _gemm_amp_weights(layer)
    amp_c = _gemm_amp_col(layer)
    out: list[AccessStream] = []
    if col:
        out.append(AccessStream(f"{layer.name}.colw", b * col, True, col))
        out.append(AccessStream(f"{layer.name}.colr", b * col, False, col))
        if amp_c > 1:
            out.append(AccessStream(f"{layer.name}.colr+",
                                    b * col * (amp_c - 1), False,
                                    TILE_REUSE_RD))
    # weights: first read per image (reuse distance = one image-layer
    # working set), plus per-output-tile re-reads that hit near the MSHRs
    out.append(AccessStream(f"{layer.name}.w", b * layer.weight_bytes, False,
                            per_image_ws if batch > 1 else INF))
    if amp_w > 1:
        out.append(AccessStream(f"{layer.name}.w+",
                                b * layer.weight_bytes * (amp_w - 1), False,
                                TILE_REUSE_RD))
    out.append(AccessStream(f"{layer.name}.ain", b * layer.act_in_bytes,
                            False, col if col else layer.act_in_bytes))
    out.append(AccessStream(f"{layer.name}.aout", b * layer.act_out_bytes,
                            True, layer.act_out_bytes + col))
    return out


def _fc_streams(layer, batch: int) -> list[AccessStream]:
    """Caffe fc: batched GEMM — weights stream once per batch."""
    b = float(batch)
    return [
        AccessStream(f"{layer.name}.w", layer.weight_bytes, False, INF),
        AccessStream(f"{layer.name}.ain", b * layer.act_in_bytes, False,
                     layer.weight_bytes),
        AccessStream(f"{layer.name}.aout", b * layer.act_out_bytes, True,
                     layer.weight_bytes),
    ]


def _backward_streams(layer, batch: int) -> list[AccessStream]:
    """Backward pass for one layer (training): dgrad + wgrad + saved acts."""
    b = float(batch)
    col = layer.im2col_bytes
    dy = layer.act_out_bytes
    dx = layer.act_in_bytes
    per_image_ws = col + dx + dy + layer.weight_bytes
    w_rd = b * layer.weight_bytes if layer.kind == "conv" else layer.weight_bytes
    amp_w = _gemm_amp_weights(layer)
    # dgrad: dX = W^T dY  (weights re-read per input tile, as forward)
    out = [AccessStream(f"{layer.name}.bw.w", w_rd, False,
                        per_image_ws if layer.kind == "conv" else INF)]
    if amp_w > 1:  # same guard as forward: no zero-byte stream at amp_w == 1
        out.append(AccessStream(f"{layer.name}.bw.w+", w_rd * (amp_w - 1),
                                False, TILE_REUSE_RD))
    out += [
        AccessStream(f"{layer.name}.bw.dy", b * dy * 2.0, False, dy + col),
        AccessStream(f"{layer.name}.bw.dx", b * dx, True, dx + col),
        # wgrad: dW = dY col^T — col rebuilt from saved activations
        AccessStream(f"{layer.name}.bw.act",
                     b * dx * BWD_ACT_REREADS, False, INF),  # saved in fwd
        AccessStream(f"{layer.name}.bw.dw", layer.weight_bytes, True, INF),
    ]
    if col:
        amp_c = _gemm_amp_col(layer)
        out.append(AccessStream(f"{layer.name}.bw.colw", b * col, True, col))
        out.append(AccessStream(f"{layer.name}.bw.colr", b * col * amp_c,
                                False, col if amp_c == 1 else TILE_REUSE_RD))
    return out


def _optimizer_streams(workload: Workload) -> list[AccessStream]:
    """SGD+momentum update: read W, M, dW; write W, M (once per batch)."""
    pbytes = float(sum(l.weight_bytes for l in workload.layers))
    return [
        AccessStream("opt.read", 3.0 * pbytes, False, INF),
        AccessStream("opt.write", 2.0 * pbytes, True, INF),
    ]


def build(workload: Workload, batch: int, training: bool) -> TrafficStats:
    streams: list[AccessStream] = []
    for layer in workload.layers:
        builder = _conv_streams if layer.kind == "conv" else _fc_streams
        streams.extend(builder(layer, batch))
    macs = float(workload.total_macs) * batch
    if training:
        for layer in workload.layers:
            streams.extend(_backward_streams(layer, batch))
        streams.extend(_optimizer_streams(workload))
        macs *= 3.0  # fwd + dgrad + wgrad
    # zero-byte streams would pollute the SoA fold arrays and the padded
    # batched tensors (workload_engine) with degenerate entries
    assert all(s.bytes_total > 0 for s in streams), \
        [s.label for s in streams if s.bytes_total <= 0]
    return TrafficStats(workload.name, batch, training, tuple(streams), macs)


# ---------------------------------------------------------------------------
# Runtime / energy / EDP (paper §III-B "simple model" + platform overlap)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """One bar of paper Figs. 3/4/7/8."""

    workload: str
    mem: str
    runtime_s: float
    dyn_read_j: float
    dyn_write_j: float
    leak_j: float
    dram_j: float

    @property
    def dyn_j(self) -> float:
        return self.dyn_read_j + self.dyn_write_j

    def total_j(self, include_dram: bool = False) -> float:
        return self.dyn_j + self.leak_j + (self.dram_j if include_dram else 0.0)

    def edp(self, include_dram: bool = False) -> float:
        return self.total_j(include_dram) * self.runtime_s


def runtime(stats: TrafficStats, design: CacheDesign,
            platform: Platform = GTX_1080TI,
            include_dram: bool = True) -> float:
    t_compute = stats.macs_per_batch * 2.0 / (platform.peak_flops
                                              * COMPUTE_EFFICIENCY)
    t_l2 = (stats.l2_read_tx * design.read_latency_s
            + stats.l2_write_tx * design.write_latency_s)
    t = t_compute + platform.mem_serialization * t_l2
    if include_dram:
        dram_tx = stats.dram_tx(design.capacity_bytes)
        t += dram_tx * LINE_BYTES / platform.dram_bw
    return t


def energy(stats: TrafficStats, design: CacheDesign,
           platform: Platform = GTX_1080TI,
           include_dram: bool = True) -> EnergyReport:
    t = runtime(stats, design, platform, include_dram)
    dram_tx = stats.dram_tx(design.capacity_bytes)
    return EnergyReport(
        workload=stats.workload,
        mem=design.mem,
        runtime_s=t,
        dyn_read_j=stats.l2_read_tx * design.read_energy_j,
        dyn_write_j=stats.l2_write_tx * design.write_energy_j,
        leak_j=design.leakage_w * t,
        dram_j=dram_tx * LINE_BYTES * platform.dram_energy_per_byte,
    )

"""Calibration of the cache model against the paper's published anchors.

The paper calibrates NVSim against a commercial 16 nm PDK; we calibrate our
structural model against the paper's own published results instead:

  * Table I  — bitcell device parameters (anchored in core/mtj.py).
  * Table II — EDAP-tuned cache designs at 3 MB (iso-capacity) and at the
               iso-area capacities (7 MB STT / 10 MB SOT).

Two kinds of constants:

  * **Absolute coefficients** (periphery area, periphery leakage): fit as
    `lin * cap_mb + sqrt * sqrt(cap_mb)` through the two Table II capacity
    anchors per technology (one anchor + a trend prior for SRAM).  These
    carry the iso-area capacity result (7 MB / 10 MB emerge from the area
    model) and the leakage scalability (Fig. 9).
  * **Multipliers** (k_* on latency/energy): ratio of the Table II value to
    the raw structural model at the EDAP-tuned 3 MB design, computed at
    import by a two-step fixed point (tune -> fit k -> re-tune -> re-fit).
    The structural model then provides org-dependence (Algorithm 1) and
    capacity scaling; the multiplier pins the absolute scale.

All paper anchor values live here so benchmarks/tests validate against a
single source of truth.

Technology nodes: the fit above is anchored at 16 nm (the paper's PDK).
``get(mem, node)`` keeps that fixed point as the single anchor and derives
non-anchor-node calibrations by scaling it — periphery area with the node's
logic-area factor, periphery leakage with the node's leakage factor, the
dimensionless k_* multipliers unchanged (the structural model they multiply
already reads the node parameters).  Only nodes produced by
``tech.scaled_node`` carry that rule; any other node raises instead of
silently inheriting 16 nm multipliers.
"""

from __future__ import annotations

import dataclasses
import functools

from repro.core import tech
from repro.core.tech import TechNode, TECH_16NM

# ---------------------------------------------------------------------------
# Paper anchors (single source of truth for tests/benchmarks)
# ---------------------------------------------------------------------------

# Table I (device level).  Latencies s, energies J, area normalized to SRAM.
TABLE1 = {
    "stt": dict(sense_lat=650e-12, sense_e=0.076e-12,
                wlat_set=8400e-12, wlat_reset=7780e-12,
                we_set=1.1e-12, we_reset=2.2e-12,
                fins_read=4, fins_write=4, area=0.34),
    "sot": dict(sense_lat=650e-12, sense_e=0.020e-12,
                wlat_set=313e-12, wlat_reset=243e-12,
                we_set=0.08e-12, we_reset=0.08e-12,
                fins_read=1, fins_write=3, area=0.29),
}

# Table II (cache level).  Capacities MB; latencies ns; energies nJ;
# leakage mW; area mm^2.
TABLE2 = {
    "sram": dict(cap=3, rlat=2.91, wlat=1.53, re=0.35, we=0.32,
                 leak=6442.0, area=5.53),
    "stt": dict(cap=3, rlat=2.98, wlat=9.31, re=0.81, we=0.31,
                leak=748.0, area=2.34),
    "sot": dict(cap=3, rlat=3.71, wlat=1.38, re=0.49, we=0.22,
                leak=527.0, area=1.95),
    "stt_isoarea": dict(cap=7, rlat=4.58, wlat=10.06, re=0.93, we=0.43,
                        leak=1706.0, area=5.12),
    "sot_isoarea": dict(cap=10, rlat=6.69, wlat=2.47, re=0.51, we=0.40,
                        leak=1434.0, area=5.64),
}

# Headline paper claims used by the validation benchmarks.
PAPER_CLAIMS = dict(
    isocap_edp_reduction_max=dict(stt=3.8, sot=4.7),
    isocap_area_reduction=dict(stt=2.4, sot=2.8),
    isocap_dyn_energy_x=dict(stt=2.1, sot=1.3),        # vs SRAM (higher)
    isocap_leak_reduction=dict(stt=5.9, sot=10.0),
    isocap_energy_reduction=dict(stt=5.1, sot=8.6),
    sram_read_share_of_dyn=0.83,
    isoarea_capacity_x=dict(stt=7 / 3, sot=10 / 3),
    isoarea_dram_reduction_pct=dict(stt=14.6, sot=19.8),
    isoarea_edp_reduction_with_dram=dict(stt=2.0, sot=2.3),
    isoarea_edp_reduction_no_dram=dict(stt=1.1, sot=1.2),
    isoarea_dyn_energy_x=dict(stt=2.5, sot=1.4),
    isoarea_leak_reduction=dict(stt=2.1, sot=2.3),
    isoarea_energy_reduction=dict(stt=2.0, sot=2.3),
    scaling_energy_reduction_max=dict(stt=31.2, sot=36.4),
    scaling_latency_reduction_max=dict(stt=2.1, sot=2.6),
    scaling_edp_reduction_max=dict(stt=65.0, sot=95.0),
    batch_sweep_train_edp=dict(stt=(2.3, 4.6), sot=(7.2, 7.6)),
    batch_sweep_infer_edp=dict(stt=(4.1, 5.4), sot=(7.1, 7.3)),
)

ISO_AREA_TOLERANCE = 1.02  # 10 MB SOT is 5.64 mm^2 vs 5.53 SRAM (+2%)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-technology calibration constants for CacheModel."""

    # periphery area [mm^2] = lin * cap_mb + sqrt * sqrt(cap_mb)
    peri_area_lin: float
    peri_area_sqrt: float
    # periphery leakage [W] = lin * cap_mb + sqrt * sqrt(cap_mb)
    leak_lin: float
    leak_sqrt: float
    # structural-model multipliers (1.0 = raw model)
    k_read_lat: float = 1.0
    k_write_lat: float = 1.0
    k_read_e: float = 1.0
    k_write_e: float = 1.0


# Absolute coefficients, derived in closed form from the Table II anchors
# (see DESIGN.md §2): array area = bits * cell_area / 0.85, periphery is the
# remainder; two capacities per MRAM tech give the (lin, sqrt) pair; SRAM
# has one anchor + an STT-shaped split prior.
_BASE = {
    "sram": Calibration(peri_area_lin=0.9000, peri_area_sqrt=0.3350,
                        leak_lin=0.2500, leak_sqrt=0.0879),
    "stt": Calibration(peri_area_lin=0.3842, peri_area_sqrt=0.2438,
                       leak_lin=0.2330, leak_sqrt=0.0281),
    "sot": Calibration(peri_area_lin=0.2423, peri_area_sqrt=0.3293,
                       leak_lin=0.1044, leak_sqrt=0.1234),
}


def _has_derivation_rule(node: TechNode) -> bool:
    """A node is calibratable iff it is the 16 nm anchor or was produced by
    ``tech.scaled_node`` (reconstructing it through the scaling rule is
    exact for those and only those).  The reconstruction bypasses the
    extrapolation guard: a node the caller built with
    ``allow_extrapolation=True`` still carries the derivation rule — the
    guard protects construction, not recognition."""
    return node == TECH_16NM or \
        tech.scaled_node(node.feature_size_m, name=node.name,
                         allow_extrapolation=True) == node


@functools.cache
def _get_cached(mem: str, node: TechNode) -> Calibration:
    if node != TECH_16NM:
        # Derived-node rule: the multipliers k_* are dimensionless factors
        # on the structural model — which itself reads the node parameters —
        # so they transfer from the anchor unchanged; the absolute periphery
        # fits scale with the node (logic area as s^PERI_AREA_EXP, periphery
        # leakage as s^PERI_LEAK_EXP).  Anything else (a hand-crafted node)
        # has no rule and must not silently inherit 16 nm constants — the
        # cross-node extrapolation failure mode Roy et al. (2023) warn about.
        if not _has_derivation_rule(node):
            raise ValueError(
                f"no calibration derivation rule for node {node.name!r}: "
                "use tech.TECH_16NM or a tech.scaled_node(...) projection")
        anchor_cal = _get_cached(mem, TECH_16NM)
        s = tech.scale_factor(node)
        return dataclasses.replace(
            anchor_cal,
            peri_area_lin=anchor_cal.peri_area_lin * s ** tech.PERI_AREA_EXP,
            peri_area_sqrt=anchor_cal.peri_area_sqrt * s ** tech.PERI_AREA_EXP,
            leak_lin=anchor_cal.leak_lin * s ** tech.PERI_LEAK_EXP,
            leak_sqrt=anchor_cal.leak_sqrt * s ** tech.PERI_LEAK_EXP,
        )

    from repro.core.cachemodel import CacheModel
    from repro.core.tuner import tune

    base = _BASE[mem]
    anchor = TABLE2[mem]
    cap_bytes = anchor["cap"] * 2**20
    cal = base
    for _ in range(2):  # tune -> fit -> re-tune with fitted k -> re-fit
        model = CacheModel(mem, calibration=cal)
        design = tune(model, cap_bytes)
        cal = dataclasses.replace(
            base,
            k_read_lat=anchor["rlat"] * 1e-9 / (design.read_latency_s / cal.k_read_lat),
            k_write_lat=anchor["wlat"] * 1e-9 / (design.write_latency_s / cal.k_write_lat),
            k_read_e=anchor["re"] * 1e-9 / (design.read_energy_j / cal.k_read_e),
            k_write_e=anchor["we"] * 1e-9 / (design.write_energy_j / cal.k_write_e),
        )
    return cal


def get(mem: str, node: TechNode = TECH_16NM) -> Calibration:
    """Fully fitted calibration for `mem` at `node` (cached).

    The 16 nm anchor runs the Table II fixed-point fit; nodes produced by
    ``tech.scaled_node`` derive from that fit via the documented scaling
    rule; any other node raises (no silent 16 nm reuse)."""
    return _get_cached(mem, node)


IDENTITY = Calibration(peri_area_lin=0.38, peri_area_sqrt=0.24,
                       leak_lin=0.23, leak_sqrt=0.03)

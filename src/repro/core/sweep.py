"""Unified cross-layer sweep pipeline — one declarative spec for every
analysis.

DeepNVM++'s value is that a single circuit + architecture stack answers
every question — iso-capacity (Figs. 3-5), iso-area (Figs. 6-8),
scalability (Figs. 9-10), and the beyond-paper LM study — from the same
models.  This module makes that literal: a :class:`SweepSpec` declares the
axes of an analysis

    scenarios  (workload, batch, training) TrafficStats — paper CNNs,
               batch sweeps, or LM (arch x shape) cells (repro.scenarios)
    designs    (memory technology, capacity, technology node) points, with
               a normalization group per point (the paper's "normalize to
               SRAM" baseline; cross-node DTCO sweeps group per node)
    platforms  compute platforms (GTX_1080TI, TPU_V5E, ...)

and ``run`` lowers it to **exactly one** circuit-engine call
(``engine.design_table`` over the unique mems x capacities) plus **one**
workload-engine call (``workload_engine.evaluate_platforms`` over the full
[platform] x [scenario] x [design] cross product).  The result is a tidy
:class:`SweepResult` with labeled axes, ``rows()`` (long-format dicts),
``norm_to("sram")`` (the figure convention), ``summary()`` aggregates, and
CSV export.

The per-analysis modules (isocap / isoarea / scaling) and the LM benchmark
are thin adapters that build a spec and materialize their historical row
shapes from the result — no analysis owns its own designs/fold plumbing.

Specs are hashable and ``run`` is memoized, so two analyses that declare
the same axes share one evaluation end to end (the engines memoize their
own layers as well, so partial overlap is also shared).

**Symbolic specs (v2).**  :class:`SymbolicSweepSpec` is the serializable
form of the same declaration: scenarios are names resolved through the
unified registry (``"cnn/resnet18/train@b64"``, ``"lm/qwen3-14b/
decode_32k"`` — repro.scenarios), designs name (mem, capacity, node)
points (``"stt@3MB@10nm"``) or declare grid/corner axes
(:class:`DesignGrid` / :class:`DesignCorners`), and platforms/nodes
resolve via the registries in core/tech.py.  ``to_json``/``from_json``
round-trip a versioned document, and ``resolve()`` lowers the symbolic
spec to a concrete :class:`SweepSpec` — through the same memoized
registry entry points, so a JSON-defined sweep shares the ``run`` memo
(and the one-circuit-call + one-fold-call guarantee) with the equivalent
Python-constructed spec.  ``python -m repro.sweep`` (repro/sweep_cli.py)
is the service facade over this document form.

On the result side, :class:`SweepResult` is a query surface:
``filter()``/``select()`` slice the labeled axes into a
:class:`SweepView`, and ``pareto_front()``/``capacity_plateaus()``
(core/dse.py) reduce multi-capacity sweeps to the non-dominated designs
and the capacity knee per scenario.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import re
from collections.abc import Iterable, Mapping, Sequence

import numpy as np

from repro.core import dse, engine, report, tech, workload_engine
from repro.core.cachemodel import CacheDesign
from repro.core.tech import Platform, GTX_1080TI, TechNode, TECH_16NM
from repro.core.traffic import TrafficStats
from repro.core.workloads import Workload

MEMS = ("sram", "stt", "sot")
BASELINE_MEM = "sram"

# The IsoCapRow.norm metric vocabulary, shared by rows()/summary().
METRICS = ("dyn", "leak", "energy", "edp", "runtime")
# rows() column name of each raw metric (EDP is J*s, runtime is s).
_ROW_FIELD = {"dyn": "dyn_j", "leak": "leak_j", "energy": "energy_j",
              "edp": "edp_js", "runtime": "runtime_s"}


# ---------------------------------------------------------------------------
# Axis declarations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One (memory technology, capacity, node) point of the design axis.

    ``group`` labels the normalization group: each group holds exactly one
    baseline-memory design, and ``norm_to`` divides every member by it
    (iso-capacity/iso-area: one group; scaling: one group per capacity;
    DTCO: one group per (node, capacity), so every node is compared against
    its own baseline).
    """

    mem: str
    capacity_bytes: int
    group: object = 0
    node: TechNode = TECH_16NM

    @property
    def capacity_mb(self) -> float:
        return self.capacity_bytes / 2**20


def design_grid(mems: Sequence[str] = MEMS,
                capacities_mb: Sequence[float] = (3,),
                nodes: TechNode | Sequence[TechNode] = (TECH_16NM,),
                ) -> tuple[DesignPoint, ...]:
    """Node-major (node x capacity x memory) cross product, one
    normalization group per (node, capacity) — the iso-capacity, scaling,
    and cross-node DTCO design axes.  Single-node grids keep the bare
    per-capacity group labels (the historical row shape)."""
    nodes = (nodes,) if isinstance(nodes, TechNode) else tuple(nodes)
    single = len(nodes) == 1
    return tuple(DesignPoint(m, int(c * 2**20),
                             group=float(c) if single else (nd.name, float(c)),
                             node=nd)
                 for nd in nodes for c in capacities_mb for m in mems)


def design_corners(points: Sequence[tuple[str, float]],
                   group: object = 0,
                   nodes: TechNode | Sequence[TechNode] = (TECH_16NM,),
                   ) -> tuple[DesignPoint, ...]:
    """Explicit (mem, capacity_mb) corners sharing one normalization group
    — the iso-area design axis (different capacities, one SRAM baseline).

    ``nodes`` replicates the corner set per node (parity with
    ``design_grid``): a single node keeps the bare ``group`` label, several
    nodes label each replica ``(node.name, group)`` so every node
    normalizes against its own baseline corner — the per-node iso-area
    comparison."""
    nodes = (nodes,) if isinstance(nodes, TechNode) else tuple(nodes)
    single = len(nodes) == 1
    return tuple(DesignPoint(m, int(c * 2**20),
                             group=group if single else (nd.name, group),
                             node=nd)
                 for nd in nodes for m, c in points)


def group_label(group: object) -> str:
    """Stable string form of a normalization-group label — the ``group``
    column of ``SweepResult.rows()``/CSV output (floats via %g, tuple
    labels slash-joined; no Python ``repr`` leaks into serialized rows)."""
    if isinstance(group, tuple):
        return "/".join(group_label(g) for g in group)
    if isinstance(group, float):
        return f"{group:g}"
    return str(group)


# ---------------------------------------------------------------------------
# Symbolic design names ("stt@3MB@10nm")
# ---------------------------------------------------------------------------

_DESIGN_NAME_RE = re.compile(
    r"(?P<mem>[a-z0-9_-]+)@(?P<cap>\d+(?:\.\d+)?)MB(?:@(?P<node>[^@]+))?\Z")


def parse_design(name: str) -> tuple[str, float, TechNode]:
    """Parse ``mem@<capacity>MB[@<node>]``; the node defaults to the
    calibrated anchor and otherwise resolves via ``tech.node``."""
    m = _DESIGN_NAME_RE.fullmatch(name)
    if not m:
        raise ValueError(f"bad design name {name!r}: expected "
                         "'mem@<capacity>MB[@<node>]', e.g. 'stt@3MB@10nm'")
    node = tech.node(m.group("node")) if m.group("node") else TECH_16NM
    return m.group("mem"), float(m.group("cap")), node


def design_name(point: DesignPoint, with_node: bool = True) -> str:
    """Symbolic name of a design point (node omitted at the anchor)."""
    name = f"{point.mem}@{point.capacity_mb:g}MB"
    if with_node and point.node != TECH_16NM:
        name += f"@{point.node.name}"
    return name


def _points_from_names(names: Sequence[str]) -> tuple[DesignPoint, ...]:
    """A flat name list resolves with ``design_grid``'s group rule: one
    normalization group per (node, capacity), bare per-capacity labels
    when all points share one node (the historical row shape)."""
    parsed = [parse_design(n) for n in names]
    single = len({node for _, _, node in parsed}) == 1
    return tuple(DesignPoint(m, int(c * 2**20),
                             group=c if single else (nd.name, c),
                             node=nd)
                 for m, c, nd in parsed)


def workload_scenarios(workloads: Mapping[str, Workload] | Iterable[Workload],
                       stages: Sequence[tuple[bool, int]],
                       stage_major: bool = False,
                       ) -> tuple[TrafficStats, ...]:
    """Scenario axis of a (workload x stage) grid, via the shared memoized
    ``workload_engine.stats_for``.  ``stages`` are (training, batch) pairs;
    ``stage_major`` controls the row-major axis (scaling iterates stages
    outermost, iso-capacity/iso-area iterate workloads outermost)."""
    items = tuple(workloads.values() if isinstance(workloads, Mapping)
                  else workloads)
    if stage_major:
        return tuple(workload_engine.stats_for(w, batch, training)
                     for training, batch in stages for w in items)
    return tuple(workload_engine.stats_for(w, batch, training)
                 for w in items for training, batch in stages)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative cross-layer sweep: scenarios x designs x platforms."""

    scenarios: tuple[TrafficStats, ...]
    designs: tuple[DesignPoint, ...]
    platforms: tuple[Platform, ...] = (GTX_1080TI,)
    baseline_mem: str = BASELINE_MEM
    name: str = "sweep"

    def __post_init__(self) -> None:
        if not (self.scenarios and self.designs and self.platforms):
            raise ValueError(f"{self.name}: every axis must be non-empty")
        keys = [(s.workload, s.batch, s.training) for s in self.scenarios]
        if len(set(keys)) != len(keys):
            raise ValueError(f"{self.name}: duplicate scenario keys")
        if len(set(self.designs)) != len(self.designs):
            raise ValueError(f"{self.name}: duplicate design points")

    def run(self, plan: ShardPlan | None = None) -> SweepResult:
        return run(self, plan)


# ---------------------------------------------------------------------------
# Symbolic SweepSpec v2: serializable, registry-resolved
# ---------------------------------------------------------------------------

SCHEMA = "deepnvm.sweepspec/2"


def _as_tuple(x: object) -> tuple:
    return x if isinstance(x, tuple) else tuple(x)


@dataclasses.dataclass(frozen=True)
class DesignGrid:
    """Symbolic (node x capacity x memory) grid — lowers via
    ``design_grid`` (one normalization group per (node, capacity))."""

    mems: tuple[str, ...] = MEMS
    capacities_mb: tuple[float, ...] = (3,)
    nodes: tuple[str, ...] = ()   # node names; empty = the 16 nm anchor

    def __post_init__(self) -> None:
        object.__setattr__(self, "mems", _as_tuple(self.mems))
        object.__setattr__(self, "capacities_mb",
                           _as_tuple(self.capacities_mb))
        object.__setattr__(self, "nodes", _as_tuple(self.nodes))

    def points(self) -> tuple[DesignPoint, ...]:
        nodes = tuple(tech.node(n) for n in self.nodes) or (TECH_16NM,)
        return design_grid(self.mems, self.capacities_mb, nodes=nodes)

    def to_doc(self) -> dict:
        doc: dict = {"mems": list(self.mems),
                     "capacities_mb": list(self.capacities_mb)}
        if self.nodes:
            doc["nodes"] = list(self.nodes)
        return doc


@dataclasses.dataclass(frozen=True)
class DesignCorners:
    """Symbolic corner set — named (mem, capacity) points sharing one
    normalization group per node, lowered via ``design_corners``.

    Two node forms, mutually exclusive:

      * the ``nodes`` field replicates a node-free corner set per node —
        the same capacities everywhere (iso-capacity across nodes);
      * node-suffixed point names ("stt@8MB@12nm-scaled") place each
        corner on its own node — per-node capacities, as the cross-node
        iso-area study needs (the area budget buys a different capacity
        at every node).  With several distinct nodes each corner joins
        the ``(node.name, group)`` normalization group, so every node
        normalizes against its own baseline corner.
    """

    points: tuple[str, ...]       # "mem@<capacity>MB[@<node>]" names
    group: object = 0
    nodes: tuple[str, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "points", _as_tuple(self.points))
        object.__setattr__(self, "nodes", _as_tuple(self.nodes))
        if isinstance(self.group, list):   # JSON arrays -> hashable labels
            object.__setattr__(self, "group", tuple(self.group))

    def corner_pairs(self) -> tuple[tuple[str, float], ...]:
        pairs = []
        for name in self.points:
            mem, cap, node = parse_design(name)
            if node != TECH_16NM:
                raise ValueError(
                    f"corner {name!r} must not name a node when the "
                    "'nodes' field replicates the set; either drop the "
                    "suffix or leave 'nodes' empty and suffix every "
                    "off-anchor corner")
            pairs.append((mem, cap))
        return tuple(pairs)

    def resolved_points(self) -> tuple[DesignPoint, ...]:
        if self.nodes:
            nodes = tuple(tech.node(n) for n in self.nodes)
            return design_corners(self.corner_pairs(), group=self.group,
                                  nodes=nodes)
        parsed = tuple(parse_design(name) for name in self.points)
        single = len({node for _, _, node in parsed}) == 1
        return tuple(
            DesignPoint(mem, int(cap * 2**20),
                        group=self.group if single
                        else (node.name, self.group),
                        node=node)
            for mem, cap, node in parsed)

    def to_doc(self) -> dict:
        doc: dict = {"points": list(self.points)}
        if self.group != 0:
            doc["group"] = self.group
        if self.nodes:
            doc["nodes"] = list(self.nodes)
        return doc


def _designs_from_doc(doc: object) -> tuple[str, ...] | DesignGrid | DesignCorners:
    if isinstance(doc, Mapping):
        if set(doc) == {"grid"}:
            return DesignGrid(**doc["grid"])
        if set(doc) == {"corners"}:
            return DesignCorners(**doc["corners"])
        raise ValueError(f"bad designs document {sorted(doc)}: expected "
                         "a name list, {'grid': ...}, or {'corners': ...}")
    return _as_tuple(doc)


@dataclasses.dataclass(frozen=True)
class SymbolicSweepSpec:
    """SweepSpec v2: the same scenarios x designs x platforms declaration
    with every axis symbolic — names resolved through registries — and a
    JSON-round-trippable, versioned document form.

    ``resolve()`` lowers to a concrete :class:`SweepSpec` through the
    memoized registry entry points, so an equal symbolic spec (however it
    was constructed — JSON, ``from_spec``, or by hand) resolves to an
    equal concrete spec and therefore shares one memoized ``run`` result.
    """

    scenarios: tuple[str, ...]
    designs: tuple[str, ...] | DesignGrid | DesignCorners
    platforms: tuple[str, ...] = (GTX_1080TI.name,)
    baseline_mem: str = BASELINE_MEM
    name: str = "sweep"

    def __post_init__(self) -> None:
        object.__setattr__(self, "scenarios", _as_tuple(self.scenarios))
        object.__setattr__(self, "platforms", _as_tuple(self.platforms))
        if not isinstance(self.designs, (DesignGrid, DesignCorners)):
            object.__setattr__(self, "designs", _as_tuple(self.designs))

    # -- lowering ----------------------------------------------------------

    def design_points(self) -> tuple[DesignPoint, ...]:
        if isinstance(self.designs, DesignGrid):
            return self.designs.points()
        if isinstance(self.designs, DesignCorners):
            return self.designs.resolved_points()
        return _points_from_names(self.designs)

    def resolve(self) -> SweepSpec:
        """Lower to a concrete spec (today's axes): scenario names through
        the unified registry, design names/grids to DesignPoints, platform
        names through ``tech.PLATFORMS``."""
        # repro.scenarios builds on this module; resolve late to keep the
        # registry layering acyclic.
        from repro import scenarios as scenario_registry
        return SweepSpec(
            name=self.name,
            scenarios=tuple(scenario_registry.resolve(n)
                            for n in self.scenarios),
            designs=self.design_points(),
            platforms=tuple(tech.platform(p) for p in self.platforms),
            baseline_mem=self.baseline_mem)

    def run(self, plan: ShardPlan | None = None) -> SweepResult:
        return self.resolve().run(plan)

    # -- (de)serialization -------------------------------------------------

    def to_doc(self) -> dict:
        designs: object = list(self.designs) \
            if isinstance(self.designs, tuple) else \
            {"grid": self.designs.to_doc()} \
            if isinstance(self.designs, DesignGrid) else \
            {"corners": self.designs.to_doc()}
        return {"schema": SCHEMA,
                "name": self.name,
                "scenarios": list(self.scenarios),
                "designs": designs,
                "platforms": list(self.platforms),
                "baseline_mem": self.baseline_mem}

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_doc(), indent=indent) + "\n"

    @classmethod
    def from_json(cls, doc: str | Mapping) -> SymbolicSweepSpec:
        if not isinstance(doc, Mapping):
            doc = json.loads(doc)
        if doc.get("schema") != SCHEMA:
            raise ValueError(f"unsupported spec schema {doc.get('schema')!r}"
                             f" (this build reads {SCHEMA!r})")
        known = {"schema", "name", "scenarios", "designs", "platforms",
                 "baseline_mem"}
        unknown = set(doc) - known
        if unknown:
            raise ValueError(f"unknown spec fields {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        missing = {"scenarios", "designs"} - set(doc)
        if missing:
            raise ValueError(f"spec document lacks {sorted(missing)}")
        return cls(
            scenarios=_as_tuple(doc["scenarios"]),
            designs=_designs_from_doc(doc["designs"]),
            platforms=_as_tuple(doc.get("platforms", (GTX_1080TI.name,))),
            baseline_mem=doc.get("baseline_mem", BASELINE_MEM),
            name=doc.get("name", "sweep"))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> SymbolicSweepSpec:
        with open(path) as f:
            return cls.from_json(f.read())

    # -- concrete -> symbolic ----------------------------------------------

    @classmethod
    def from_spec(cls, spec: SweepSpec) -> SymbolicSweepSpec:
        """Symbolize a concrete spec (golden-file generation, serving).
        Scenario names come from the registry's inverse mapping; designs
        become a flat name list when their groups follow the grid rule, a
        corner set when they share one group.  Custom group labelings have
        no symbolic form and raise."""
        from repro import scenarios as scenario_registry
        return cls(
            scenarios=tuple(scenario_registry.name_of(s)
                            for s in spec.scenarios),
            designs=_symbolic_designs(spec.designs),
            platforms=tuple(p.name for p in spec.platforms),
            baseline_mem=spec.baseline_mem,
            name=spec.name)


def _symbolic_designs(points: Sequence[DesignPoint],
                      ) -> tuple[str, ...] | DesignCorners:
    single = len({p.node for p in points}) == 1
    def grid_group(p: DesignPoint) -> object:
        return float(p.capacity_mb) if single \
            else (p.node.name, float(p.capacity_mb))
    if all(p.group == grid_group(p) for p in points):
        return tuple(design_name(p) for p in points)
    groups = {p.group for p in points}
    if single and len(groups) == 1:
        node = points[0].node
        return DesignCorners(
            points=tuple(design_name(p, with_node=False) for p in points),
            group=next(iter(groups)),
            nodes=() if node == TECH_16NM else (node.name,))
    # multi-node corner sets: per-point (node.name, G) groups sharing one G
    # symbolize as node-suffixed corner names (the cross-node iso-area form)
    shared = {g[1] for g in groups if isinstance(g, tuple) and len(g) == 2}
    if not single and len(shared) == 1:
        g = next(iter(shared))
        if all(p.group == (p.node.name, g) for p in points):
            return DesignCorners(
                points=tuple(design_name(p) for p in points), group=g)
    raise ValueError("designs with custom normalization groups have no "
                     "symbolic form; serialize grid- or corner-shaped axes")


def load_spec(path: str) -> SymbolicSweepSpec:
    """Module-level convenience: read a spec JSON document."""
    return SymbolicSweepSpec.load(path)


# ---------------------------------------------------------------------------
# Lowering: spec -> one circuit call + one workload-fold call
# ---------------------------------------------------------------------------


def lower_designs(points: Sequence[DesignPoint], pad_caps: bool = False,
                  ) -> tuple[engine.DesignTable, tuple[CacheDesign, ...]]:
    """One memoized ``engine.design_table`` over the unique nodes, mems,
    and capacities, then the EDAP-tuned design of every point (Algorithm 1,
    memoized per (node, mem, capacity) on the table).

    ``pad_caps`` pads the capacity axis to its power-of-two bucket with
    deterministic dummy capacities before the circuit call and slices the
    table back to the real axis after tuning, so the PPA kernel only ever
    compiles at O(log) capacity counts — the sweep service's warmup-able
    path.  Tuning is a per-(node, mem, capacity) argmin over the
    organization axis, so the tuned designs are bit-identical to the
    unpadded ones; only the kernel *shape* changes."""
    nodes = tuple(dict.fromkeys(p.node for p in points))
    mems = tuple(dict.fromkeys(p.mem for p in points))
    caps = tuple(dict.fromkeys(p.capacity_bytes for p in points))
    lowered = _pad_capacities(caps) if pad_caps else caps
    table = engine.design_table(mems, lowered, nodes=nodes)
    designs = tuple(table.tuned(p.mem, p.capacity_bytes, node=p.node)
                    for p in points)
    if lowered is not caps:
        # drop the dummy columns; Algorithm-1 winners carry over
        table = table.subset(capacities_bytes=caps)
    return table, designs


def _pad_capacities(caps: tuple[int, ...]) -> tuple[int, ...]:
    """Pad a unique-capacity tuple to its power-of-two bucket with dummy
    capacities just above the real maximum (64-byte steps, skipping any
    collision with a real value) — deterministic, so the padded tuple and
    therefore the ``engine.design_table`` memo key are stable per real
    capacity set."""
    target = workload_engine.axis_bucket(len(caps))
    if target == len(caps):
        return caps
    used = set(caps)
    pad: list[int] = []
    c = max(caps)
    while len(caps) + len(pad) < target:
        c += 64
        if c not in used:
            pad.append(c)
            used.add(c)
    return caps + tuple(pad)


@functools.lru_cache(maxsize=None)
def _run_cached(spec: SweepSpec) -> SweepResult:
    table, designs = lower_designs(spec.designs)
    tables = workload_engine.evaluate_platforms(spec.scenarios, designs,
                                                spec.platforms)
    return SweepResult(spec=spec, design_table=table, designs=designs,
                       tables=tables)


def run(spec: SweepSpec, plan: ShardPlan | None = None) -> SweepResult:
    """Lower and evaluate a spec.

    Without a plan: exactly one ``engine.design_table`` call plus one
    ``workload_engine.evaluate_platforms`` call, memoized per spec so
    equal specs share one SweepResult object.

    With a :class:`ShardPlan`: the chunked/sharded lowering —
    ``run_sharded(spec, plan)`` — which streams partial results through
    ``SweepResult.merge`` instead of materializing one mega-tensor (and
    is deliberately *not* memoized: mega-results are too large to pin)."""
    if plan is not None:
        return run_sharded(spec, plan)
    return _run_cached(spec)


def n_cells(spec: SweepSpec) -> int:
    """Evaluated cells of a spec: platforms x scenarios x designs."""
    return len(spec.platforms) * len(spec.scenarios) * len(spec.designs)


def clear_cache() -> None:
    """Drop memoized sweep results (benchmark reruns; the engine-layer
    caches are cleared separately via their own hooks)."""
    _run_cached.cache_clear()


# ---------------------------------------------------------------------------
# Sharded lowering: ShardPlan -> chunks -> streaming merge
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """How to split a sweep into independently evaluated chunks.

    ``scenario_chunk`` / ``design_chunk`` bound the chunk extent along
    each axis (None = don't split that axis).  ``devices`` > 0 additionally
    shard_maps same-shaped chunk groups over a 1-D device mesh
    (``distributed.sharding.sweep_mesh``); None keeps chunks on the
    default device.  ``by_width`` orders scenarios by stream count before
    chunking, so wide outliers (googlenet train: 645 streams) share chunks
    and the padded-SoA area of the stream tensors stays near-minimal.
    """

    scenario_chunk: int | None = None
    design_chunk: int | None = None
    devices: int | None = None
    by_width: bool = False

    def __post_init__(self) -> None:
        for field in ("scenario_chunk", "design_chunk", "devices"):
            v = getattr(self, field)
            if v is not None and (not isinstance(v, int) or v < 1):
                raise ValueError(f"{field} must be a positive int or None,"
                                 f" got {v!r}")


def split(spec: SweepSpec, plan: ShardPlan) -> tuple[SweepSpec, ...]:
    """Split a spec into the plan's grid of sub-specs: every (scenario
    block) x (design block) becomes one independent chunk spec sharing the
    parent's platforms and baseline.  The union of chunk cells tiles the
    parent's cross product exactly once (``SweepResult.merge`` validates
    this on reassembly)."""
    sc = plan.scenario_chunk or len(spec.scenarios)
    dc = plan.design_chunk or len(spec.designs)
    s_order = sorted(range(len(spec.scenarios)),
                     key=lambda i: -len(spec.scenarios[i].streams)) \
        if plan.by_width else list(range(len(spec.scenarios)))
    s_blocks = [tuple(s_order[i:i + sc])
                for i in range(0, len(s_order), sc)]
    d_blocks = [tuple(range(j, min(j + dc, len(spec.designs))))
                for j in range(0, len(spec.designs), dc)]
    return tuple(
        SweepSpec(name=f"{spec.name}#{si}.{di}",
                  scenarios=tuple(spec.scenarios[i] for i in s_block),
                  designs=tuple(spec.designs[j] for j in d_block),
                  platforms=spec.platforms,
                  baseline_mem=spec.baseline_mem)
        for si, s_block in enumerate(s_blocks)
        for di, d_block in enumerate(d_blocks))


def _chunk_result(sub: SweepSpec, table: engine.DesignTable,
                  design_of: Mapping[DesignPoint, CacheDesign],
                  tables: tuple[workload_engine.WorkloadTable, ...] | None
                  = None) -> SweepResult:
    designs = tuple(design_of[p] for p in sub.designs)
    if tables is None:
        tables = workload_engine.evaluate_chunk(sub.scenarios, designs,
                                                sub.platforms)
    sub_table = table.subset(
        mems=tuple(dict.fromkeys(p.mem for p in sub.designs)),
        capacities_bytes=tuple(dict.fromkeys(p.capacity_bytes
                                             for p in sub.designs)),
        nodes=tuple(dict.fromkeys(p.node for p in sub.designs)))
    return SweepResult(spec=sub, design_table=sub_table, designs=designs,
                       tables=tables)


def iter_shards(spec: SweepSpec, plan: ShardPlan):
    """Evaluate a spec chunk by chunk, yielding one partial SweepResult
    per chunk — the streaming form of ``run_sharded``.

    The circuit layer is lowered **once** up front (one memoized
    ``engine.design_table`` + Algorithm-1 tuning over the full design
    axis); each chunk then folds its own scenarios x designs block through
    an uncached, chunk-packed ``workload_engine`` call, so peak memory is
    bounded by one chunk's stream tensors plus the partial results.  With
    ``plan.devices``, same-shaped chunks are grouped and shard_mapped over
    the sweep mesh, ``devices`` chunks at a time.
    """
    table, designs = lower_designs(spec.designs)
    design_of = dict(zip(spec.designs, designs))
    subs = split(spec, plan)
    if plan.devices is None:
        for sub in subs:
            yield _chunk_result(sub, table, design_of)
        return
    from repro.distributed.sharding import sweep_mesh
    mesh = sweep_mesh(plan.devices)
    g = mesh.devices.size
    groups: dict[tuple[int, int, int], list[SweepSpec]] = {}
    for sub in subs:
        sig = (len(sub.scenarios), len(sub.designs),
               workload_engine.pad_width(max(len(s.streams)
                                             for s in sub.scenarios)))
        groups.setdefault(sig, []).append(sub)
    for members in groups.values():
        full = len(members) - len(members) % g
        for i in range(0, full, g):
            batch = members[i:i + g]
            tables_list = workload_engine.evaluate_chunk_group(
                [b.scenarios for b in batch],
                [[design_of[p] for p in b.designs] for b in batch],
                spec.platforms, mesh)
            for sub, tabs in zip(batch, tables_list):
                yield _chunk_result(sub, table, design_of, tabs)
        for sub in members[full:]:   # ragged tail: plain jit path
            yield _chunk_result(sub, table, design_of)


def run_sharded(spec: SweepSpec, plan: ShardPlan,
                progress=None) -> SweepResult:
    """Chunked/sharded evaluation: stream every chunk of ``split(spec,
    plan)`` through the order-invariant merge.  ``progress(i, total,
    part)`` is called per completed chunk (the CLI's stderr ticker).
    Merged output is pinned to the unsharded path at <= 1e-12 (chunk
    packing may pad reductions differently, so the last ulps can move)."""
    total = len(split(spec, plan))

    def parts():
        for i, part in enumerate(iter_shards(spec, plan)):
            if progress is not None:
                progress(i + 1, total, part)
            yield part

    return merge_results(parts(), spec=spec)


# -- merge: order-invariant reassembly of partial results -------------------

_SHARED_S = ("l2_read_tx", "l2_write_tx")
_SHARED_SD = ("dram_tx", "dyn_read_j", "dyn_write_j")


def _scenario_key(stats: TrafficStats) -> tuple[str, int, bool]:
    return (stats.workload, stats.batch, stats.training)


def _design_sort_key(p: DesignPoint):
    return (p.mem, p.capacity_bytes, p.node.name, group_label(p.group))


def merge_results(parts: Iterable[SweepResult],
                  spec: SweepSpec | None = None) -> SweepResult:
    """Reassemble partial SweepResults into one result.

    The parts' (scenario x design) blocks must tile the merged cross
    product exactly — overlapping cells raise immediately, missing cells
    raise at the end — and all parts must agree on platforms and baseline.
    With ``spec``, axes follow the spec's order and parts are **streamed**
    into preallocated tensors (consumed-and-dropped, the bounded-memory
    path ``run_sharded`` uses); without it, parts are collected first and
    the merged axes take a canonical sorted order, which is what makes the
    merge order-invariant and associative (any grouping of parts whose
    intermediate unions stay rectangular merges to the identical result).
    """
    if spec is None:
        parts = list(parts)
        if not parts:
            raise ValueError("merge needs at least one partial result")
        scen_of: dict[tuple, TrafficStats] = {}
        points: set[DesignPoint] = set()
        for part in parts:
            for s in part.spec.scenarios:
                scen_of.setdefault(_scenario_key(s), s)
            points.update(part.spec.designs)
        spec = SweepSpec(
            name=parts[0].spec.name.partition("#")[0],
            scenarios=tuple(scen_of[k] for k in sorted(scen_of)),
            designs=tuple(sorted(points, key=_design_sort_key)),
            platforms=parts[0].spec.platforms,
            baseline_mem=parts[0].spec.baseline_mem)
    s_index = {_scenario_key(s): i for i, s in enumerate(spec.scenarios)}
    d_index = {p: j for j, p in enumerate(spec.designs)}
    n_p, n_s, n_d = (len(spec.platforms), len(spec.scenarios),
                     len(spec.designs))
    cov = np.zeros((n_s, n_d), dtype=np.int8)
    shared_s = {f: np.zeros(n_s) for f in _SHARED_S}
    shared_sd = {f: np.zeros((n_s, n_d)) for f in _SHARED_SD}
    platdep = {f: np.zeros((n_p, n_s, n_d))
               for f in workload_engine._PLATFORM_DEPENDENT}
    designs: list[CacheDesign | None] = [None] * n_d
    got_any = False
    for part in parts:
        got_any = True
        if part.spec.platforms != spec.platforms:
            raise ValueError(
                f"chunk {part.spec.name!r} platforms differ from the "
                "merge target's")
        if part.spec.baseline_mem != spec.baseline_mem:
            raise ValueError(
                f"chunk {part.spec.name!r} baseline_mem differs from the "
                "merge target's")
        try:
            srows = [s_index[k] for k in part.scenario_labels]
            dcols = [d_index[p] for p in part.spec.designs]
        except KeyError as e:
            raise ValueError(f"chunk {part.spec.name!r} carries an axis "
                             f"label outside the merge target: {e}") \
                from None
        block = np.ix_(srows, dcols)
        if cov[block].any():
            raise ValueError(
                f"overlapping chunks: {part.spec.name!r} re-covers "
                "already-merged (scenario, design) cells")
        cov[block] = 1
        for j, d in zip(dcols, part.designs):
            designs[j] = d
        t0 = part.tables[0]
        for f in _SHARED_S:
            shared_s[f][srows] = getattr(t0, f)
        for f in _SHARED_SD:
            shared_sd[f][block] = getattr(t0, f)
        for pi in range(n_p):
            for f in workload_engine._PLATFORM_DEPENDENT:
                platdep[f][pi][block] = getattr(part.tables[pi], f)
    if not got_any:
        raise ValueError("merge needs at least one partial result")
    if not cov.all():
        missing = int((cov == 0).sum())
        raise ValueError(
            f"merged chunks do not tile the sweep: {missing} of "
            f"{n_s * n_d} (scenario, design) cells uncovered")
    table, _ = lower_designs(spec.designs)
    keys = tuple(_scenario_key(s) for s in spec.scenarios)
    tables = tuple(
        workload_engine.WorkloadTable(
            scenarios=keys, designs=tuple(designs), platform=p,
            **shared_s, **shared_sd,
            **{f: platdep[f][pi]
               for f in workload_engine._PLATFORM_DEPENDENT})
        for pi, p in enumerate(spec.platforms))
    return SweepResult(spec=spec, design_table=table,
                       designs=tuple(designs), tables=tables)


# -- union: superset spec of compatible requests (service coalescing) -------


def spec_union(specs: Sequence[SweepSpec], name: str | None = None,
               ) -> SweepSpec:
    """The smallest spec covering every member — the coalescing superset
    the concurrent sweep service evaluates once and slices per-request
    views out of (``SweepResult.subset``, the inverse of ``merge``).

    Compatibility rule: every member must declare the identical platform
    axis (same platforms, same order) — platform count changes the fold's
    compiled shape and a mismatched axis cannot share one evaluation.
    Scenario axes union by (workload, batch, training) key and design axes
    by DesignPoint identity (which includes the normalization group, so
    the same (mem, capacity, node) under two groupings stays two columns),
    both in first-seen order.  ``baseline_mem`` need *not* agree: each
    request's subset result carries the request's own spec, so
    normalization happens per request, never on the union.
    """
    specs = tuple(specs)
    if not specs:
        raise ValueError("spec_union needs at least one spec")
    first = specs[0]
    for sp in specs[1:]:
        if sp.platforms != first.platforms:
            raise ValueError(
                f"incompatible specs: {sp.name!r} declares a different "
                f"platform axis than {first.name!r}")
    if len(specs) == 1:
        return first
    scen: dict[tuple, TrafficStats] = {}
    points: dict[DesignPoint, None] = {}
    for sp in specs:
        for s in sp.scenarios:
            scen.setdefault(_scenario_key(s), s)
        for p in sp.designs:
            points.setdefault(p)
    return SweepSpec(
        name=name if name is not None else f"union[{len(specs)}]",
        scenarios=tuple(scen.values()),
        designs=tuple(points),
        platforms=first.platforms,
        baseline_mem=first.baseline_mem)


# ---------------------------------------------------------------------------
# Result: labeled axes + tidy views
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class SweepResult:
    """Evaluated sweep: [platform] x [scenario] x [design] tensors.

    ``tables[i]`` is the WorkloadTable view of platform i (one shared
    kernel evaluation); ``design_table`` is the circuit-engine sweep the
    designs were tuned from.
    """

    spec: SweepSpec
    design_table: engine.DesignTable
    designs: tuple[CacheDesign, ...]
    tables: tuple[workload_engine.WorkloadTable, ...]

    @classmethod
    def merge(cls, parts: Iterable[SweepResult],
              spec: SweepSpec | None = None) -> SweepResult:
        """Order-invariant reassembly of disjoint partial results — see
        :func:`merge_results`."""
        return merge_results(parts, spec=spec)

    def subset(self, spec: SweepSpec) -> SweepResult:
        """Slice this result down to a member spec — the inverse of
        ``merge`` and the per-request view of a coalesced superset
        evaluation (:func:`spec_union`).

        Every scenario key, design point, and platform of ``spec`` must be
        present in this result (axes may reorder).  The returned result
        carries ``spec`` itself — including its own ``baseline_mem`` and
        normalization groups — so ``rows()``/``summary()`` match an
        individual evaluation of ``spec``; no metric is recomputed, only
        sliced."""
        s_index = {k: i for i, k in enumerate(self.scenario_labels)}
        d_index = {p: j for j, p in enumerate(self.spec.designs)}
        p_index = {p: i for i, p in enumerate(self.spec.platforms)}
        try:
            srows = [s_index[_scenario_key(s)] for s in spec.scenarios]
            dcols = [d_index[p] for p in spec.designs]
            prows = [p_index[p] for p in spec.platforms]
        except KeyError as e:
            raise ValueError(f"subset spec {spec.name!r} has an axis label "
                             f"outside this result: {e}") from None
        block = np.ix_(srows, dcols)
        keys = tuple(_scenario_key(s) for s in spec.scenarios)
        designs = tuple(self.designs[j] for j in dcols)
        sd_fields = _SHARED_SD + workload_engine._PLATFORM_DEPENDENT
        tables = tuple(
            workload_engine.WorkloadTable(
                scenarios=keys, designs=designs,
                platform=self.spec.platforms[pi],
                **{f: getattr(self.tables[pi], f)[srows]
                   for f in _SHARED_S},
                **{f: getattr(self.tables[pi], f)[block]
                   for f in sd_fields})
            for pi in prows)
        table = self.design_table.subset(
            mems=tuple(dict.fromkeys(p.mem for p in spec.designs)),
            capacities_bytes=tuple(dict.fromkeys(p.capacity_bytes
                                                 for p in spec.designs)),
            nodes=tuple(dict.fromkeys(p.node for p in spec.designs)))
        return SweepResult(spec=spec, design_table=table, designs=designs,
                           tables=tables)

    # -- labeled axes ------------------------------------------------------

    @property
    def scenario_labels(self) -> tuple[tuple[str, int, bool], ...]:
        """(workload, batch, training) per scenario row."""
        return self.tables[0].scenarios

    @property
    def design_labels(self) -> tuple[tuple[str, float, str], ...]:
        """(mem, capacity_mb, node_name) per design column."""
        return tuple((p.mem, p.capacity_mb, p.node.name)
                     for p in self.spec.designs)

    @property
    def platform_labels(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.spec.platforms)

    @property
    def axes(self) -> dict[str, tuple]:
        return {"platform": self.platform_labels,
                "scenario": self.scenario_labels,
                "design": self.design_labels}

    def design_index(self, mem: str, capacity_mb: float | None = None,
                     node: TechNode | str | None = None) -> int:
        node_name = node.name if isinstance(node, TechNode) else node
        matches = [j for j, p in enumerate(self.spec.designs)
                   if p.mem == mem
                   and capacity_mb in (None, p.capacity_mb)
                   and node_name in (None, p.node.name)]
        if not matches:
            raise ValueError(
                f"no design ({mem}, {capacity_mb}, {node_name}) in sweep")
        if len(matches) > 1:
            raise ValueError(
                f"ambiguous design ({mem}, {capacity_mb}, {node_name})")
        return matches[0]

    # -- metric tensors ----------------------------------------------------

    def metric(self, name: str, include_dram: bool = False) -> np.ndarray:
        """[p, s, d] tensor of one METRICS entry."""
        return np.stack([t.metric(name, include_dram) for t in self.tables])

    @property
    def dram_tx(self) -> np.ndarray:
        """[s, d] DRAM transactions (platform-independent)."""
        return self.tables[0].dram_tx

    @property
    def read_write_ratio(self) -> np.ndarray:
        """[s] L2 read/write transaction ratio (platform-independent)."""
        return self.tables[0].read_write_ratio

    # -- normalization (the paper's figure convention) ---------------------

    def baseline_indices(self, baseline_mem: str | None = None) -> np.ndarray:
        """[d] index of each design's normalization baseline: the unique
        baseline-memory design of its group."""
        base = baseline_mem if baseline_mem is not None \
            else self.spec.baseline_mem
        by_group: dict[object, int] = {}
        for j, p in enumerate(self.spec.designs):
            if p.mem == base:
                if p.group in by_group:
                    raise ValueError(
                        f"group {p.group!r} has several {base!r} designs")
                by_group[p.group] = j
        missing = {p.group for p in self.spec.designs} - set(by_group)
        if missing:
            raise ValueError(f"groups {sorted(map(repr, missing))} have no "
                             f"{base!r} baseline design")
        return np.array([by_group[p.group] for p in self.spec.designs])

    def norm_to(self, baseline_mem: str | None = None) -> NormalizedSweep:
        """Metrics normalized to the baseline design of each group."""
        return NormalizedSweep(self, self.baseline_indices(baseline_mem))

    # -- labeled-axis attributes (rows()/filter()/dse vocabulary) ----------

    def scenario_attrs(self, i: int) -> dict:
        workload, batch, training = self.scenario_labels[i]
        return dict(workload=workload, batch=batch,
                    stage="train" if training else "infer")

    def design_attrs(self, j: int) -> dict:
        p = self.spec.designs[j]
        return dict(mem=p.mem, capacity_mb=p.capacity_mb, node=p.node.name,
                    group=group_label(p.group))

    # -- query surface -----------------------------------------------------

    def view(self) -> SweepView:
        """The whole result as a filterable view."""
        return SweepView(self,
                         tuple(range(len(self.platform_labels))),
                         tuple(range(len(self.scenario_labels))),
                         tuple(range(len(self.spec.designs))))

    def filter(self, **criteria) -> SweepView:
        """Select by labeled-axis attributes — ``platform``, scenario keys
        (``workload``/``batch``/``stage``/``training``), design keys
        (``mem``/``capacity_mb``/``node``/``group``).  A criterion is a
        scalar, a collection (membership), or a predicate."""
        return self.view().filter(**criteria)

    def select(self, *fields: str, include_dram: bool = False) -> list[tuple]:
        return self.view().select(*fields, include_dram=include_dram)

    # -- DSE reductions (core/dse.py) --------------------------------------

    def pareto_front(self, objectives: Sequence[str] = dse.DEFAULT_OBJECTIVES,
                     include_dram: bool = False) -> list[dict]:
        """Per-(platform, scenario) non-dominated designs over the given
        minimize-objectives (default energy/runtime/area)."""
        return dse.pareto_front(self, objectives, include_dram)

    def capacity_plateaus(self, metric: str = "edp",
                          include_dram: bool = True,
                          rel_tol: float = 0.05) -> list[dict]:
        """Per-(platform, scenario, mem, node) capacity knee: the smallest
        capacity within ``rel_tol`` of the best over the capacity axis."""
        return dse.capacity_plateaus(self, metric, include_dram, rel_tol)

    # -- tidy materialization ----------------------------------------------

    def rows(self, include_norm: bool = True,
             include_dram: bool = False) -> list[dict]:
        """Long-format rows: one dict per (platform, scenario, design)."""
        return self.view().rows(include_norm, include_dram)

    def summary(self, include_dram: bool = True) -> dict:
        """Per-(platform, non-baseline mem) aggregate reductions over all
        scenarios and design groups (the §IV prose-claim shape)."""
        norm = self.norm_to()
        energy = norm.metric("energy", include_dram=False)
        edp = norm.metric("edp", include_dram=include_dram)
        dyn = norm.metric("dyn")
        leak = norm.metric("leak")
        base = self.baseline_indices()
        out: dict[str, dict[str, dict[str, float]]] = {}
        for pi, platform in enumerate(self.platform_labels):
            per_mem: dict[str, dict[str, float]] = {}
            for mem in dict.fromkeys(p.mem for p in self.spec.designs):
                if mem == self.spec.baseline_mem:
                    continue
                cols = [j for j, p in enumerate(self.spec.designs)
                        if p.mem == mem and base[j] != j]
                if not cols:
                    continue
                per_mem[mem] = dict(
                    dyn_energy_x=float(dyn[pi][:, cols].mean()),
                    leak_reduction=float((1.0 / leak[pi][:, cols]).mean()),
                    energy_reduction=float(
                        (1.0 / energy[pi][:, cols]).mean()),
                    edp_reduction_mean=float((1.0 / edp[pi][:, cols]).mean()),
                    edp_reduction_max=float((1.0 / edp[pi][:, cols]).max()),
                )
            out[platform] = per_mem
        return out

    def to_csv(self, path: str, include_norm: bool = True,
               include_dram: bool = False, exact: bool = False) -> None:
        """Write rows as CSV.  ``exact`` keeps full float precision (repr
        round-trip — the CLI's bit-for-bit reproduction mode) instead of
        the human-readable rounding."""
        report.write_csv(path, self.rows(include_norm, include_dram),
                         fmt=report.fmt_exact if exact else None)


@dataclasses.dataclass(frozen=True, eq=False)
class NormalizedSweep:
    """View of a SweepResult with every metric divided by its group's
    baseline design (elementwise, the scalar IsoCapRow.norm convention)."""

    result: SweepResult
    baseline: np.ndarray  # [d] baseline design index per design

    def metric(self, name: str, include_dram: bool = False) -> np.ndarray:
        m = self.result.metric(name, include_dram)
        return m / m[:, :, self.baseline]


# ---------------------------------------------------------------------------
# SweepView: filter/select on labeled axes
# ---------------------------------------------------------------------------


def _match(criterion: object, value: object) -> bool:
    if callable(criterion):
        return bool(criterion(value))
    if isinstance(criterion, (list, tuple, set, frozenset)):
        return value in criterion
    return value == criterion


_SCENARIO_KEYS = ("workload", "batch", "stage", "training")
_DESIGN_KEYS = ("mem", "capacity_mb", "node", "group")


@dataclasses.dataclass(frozen=True, eq=False)
class SweepView:
    """Index selection on a SweepResult's [platform, scenario, design]
    axes — the query layer ``filter()`` chains on.  Metric tensors are
    sliced from the shared result (nothing is re-evaluated); ``rows()``
    normalization baselines stay those of the *full* result, so a filtered
    view reports the same normalized values as the full row set."""

    result: SweepResult
    platform_ids: tuple[int, ...]
    scenario_ids: tuple[int, ...]
    design_ids: tuple[int, ...]

    def __len__(self) -> int:
        return (len(self.platform_ids) * len(self.scenario_ids)
                * len(self.design_ids))

    # -- filtering ---------------------------------------------------------

    def filter(self, **criteria) -> SweepView:
        known = ("platform",) + _SCENARIO_KEYS + _DESIGN_KEYS
        unknown = set(criteria) - set(known)
        if unknown:
            raise ValueError(f"unknown filter keys {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        r = self.result
        p_ids = tuple(i for i in self.platform_ids
                      if "platform" not in criteria
                      or _match(criteria["platform"], r.platform_labels[i]))
        s_ids = tuple(i for i in self.scenario_ids
                      if self._scenario_ok(i, criteria))
        d_ids = tuple(j for j in self.design_ids
                      if self._design_ok(j, criteria))
        return SweepView(r, p_ids, s_ids, d_ids)

    def _scenario_ok(self, i: int, criteria: Mapping) -> bool:
        attrs = self.result.scenario_attrs(i)
        attrs["training"] = attrs["stage"] == "train"
        return all(_match(criteria[k], attrs[k])
                   for k in _SCENARIO_KEYS if k in criteria)

    def _design_ok(self, j: int, criteria: Mapping) -> bool:
        point = self.result.spec.designs[j]
        for key in _DESIGN_KEYS:
            if key not in criteria:
                continue
            crit = criteria[key]
            if key == "node":
                crit = crit.name if isinstance(crit, TechNode) else crit
                ok = _match(crit, point.node.name)
            elif key == "group":
                # raw group objects and their stable labels both match; a
                # criterion equal to the raw group compares directly, so
                # tuple groups (DTCO) don't read as membership collections
                ok = crit == point.group or _match(crit, point.group) \
                    or _match(crit, group_label(point.group))
            else:
                ok = _match(crit, getattr(point, key))
            if not ok:
                return False
        return True

    # -- materialization ---------------------------------------------------

    def metric(self, name: str, include_dram: bool = False) -> np.ndarray:
        """[p', s', d'] slice of one METRICS tensor."""
        m = self.result.metric(name, include_dram)
        return m[np.ix_(self.platform_ids, self.scenario_ids,
                        self.design_ids)]

    def rows(self, include_norm: bool = True,
             include_dram: bool = False) -> list[dict]:
        r = self.result
        m = {name: r.metric(name, include_dram) for name in METRICS}
        x = {name: r.norm_to().metric(name, include_dram)
             for name in METRICS} if include_norm else {}
        out = []
        for pi in self.platform_ids:
            for si in self.scenario_ids:
                for di in self.design_ids:
                    row = dict(platform=r.platform_labels[pi],
                               **r.scenario_attrs(si),
                               **r.design_attrs(di))
                    row.update({_ROW_FIELD[k]: float(v[pi, si, di])
                                for k, v in m.items()})
                    row.update({f"{k}_x": float(v[pi, si, di])
                                for k, v in x.items()})
                    out.append(row)
        return out

    def select(self, *fields: str, include_dram: bool = False) -> list[tuple]:
        """Project rows onto the named columns (raw metric columns,
        ``*_x`` normalized columns, or axis labels)."""
        needs_norm = any(f.endswith("_x") for f in fields)
        rows = self.rows(include_norm=needs_norm, include_dram=include_dram)
        if rows and (bad := set(fields) - set(rows[0])):
            raise ValueError(f"unknown columns {sorted(bad)}; available: "
                             f"{sorted(rows[0])}")
        return [tuple(r[f] for f in fields) for r in rows]

"""Unified cross-layer sweep pipeline — one declarative spec for every
analysis.

DeepNVM++'s value is that a single circuit + architecture stack answers
every question — iso-capacity (Figs. 3-5), iso-area (Figs. 6-8),
scalability (Figs. 9-10), and the beyond-paper LM study — from the same
models.  This module makes that literal: a :class:`SweepSpec` declares the
axes of an analysis

    scenarios  (workload, batch, training) TrafficStats — paper CNNs,
               batch sweeps, or LM (arch x shape) cells (repro.scenarios)
    designs    (memory technology, capacity, technology node) points, with
               a normalization group per point (the paper's "normalize to
               SRAM" baseline; cross-node DTCO sweeps group per node)
    platforms  compute platforms (GTX_1080TI, TPU_V5E, ...)

and ``run`` lowers it to **exactly one** circuit-engine call
(``engine.design_table`` over the unique mems x capacities) plus **one**
workload-engine call (``workload_engine.evaluate_platforms`` over the full
[platform] x [scenario] x [design] cross product).  The result is a tidy
:class:`SweepResult` with labeled axes, ``rows()`` (long-format dicts),
``norm_to("sram")`` (the figure convention), ``summary()`` aggregates, and
CSV export.

The per-analysis modules (isocap / isoarea / scaling) and the LM benchmark
are thin adapters that build a spec and materialize their historical row
shapes from the result — no analysis owns its own designs/fold plumbing.

Specs are hashable and ``run`` is memoized, so two analyses that declare
the same axes share one evaluation end to end (the engines memoize their
own layers as well, so partial overlap is also shared).
"""

from __future__ import annotations

import dataclasses
import functools
from collections.abc import Iterable, Mapping, Sequence

import numpy as np

from repro.core import engine, report, workload_engine
from repro.core.cachemodel import CacheDesign
from repro.core.tech import Platform, GTX_1080TI, TechNode, TECH_16NM
from repro.core.traffic import TrafficStats
from repro.core.workloads import Workload

MEMS = ("sram", "stt", "sot")
BASELINE_MEM = "sram"

# The IsoCapRow.norm metric vocabulary, shared by rows()/summary().
METRICS = ("dyn", "leak", "energy", "edp", "runtime")
# rows() column name of each raw metric (EDP is J*s, runtime is s).
_ROW_FIELD = {"dyn": "dyn_j", "leak": "leak_j", "energy": "energy_j",
              "edp": "edp_js", "runtime": "runtime_s"}


# ---------------------------------------------------------------------------
# Axis declarations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One (memory technology, capacity, node) point of the design axis.

    ``group`` labels the normalization group: each group holds exactly one
    baseline-memory design, and ``norm_to`` divides every member by it
    (iso-capacity/iso-area: one group; scaling: one group per capacity;
    DTCO: one group per (node, capacity), so every node is compared against
    its own baseline).
    """

    mem: str
    capacity_bytes: int
    group: object = 0
    node: TechNode = TECH_16NM

    @property
    def capacity_mb(self) -> float:
        return self.capacity_bytes / 2**20


def design_grid(mems: Sequence[str] = MEMS,
                capacities_mb: Sequence[float] = (3,),
                nodes: TechNode | Sequence[TechNode] = (TECH_16NM,),
                ) -> tuple[DesignPoint, ...]:
    """Node-major (node x capacity x memory) cross product, one
    normalization group per (node, capacity) — the iso-capacity, scaling,
    and cross-node DTCO design axes.  Single-node grids keep the bare
    per-capacity group labels (the historical row shape)."""
    nodes = (nodes,) if isinstance(nodes, TechNode) else tuple(nodes)
    single = len(nodes) == 1
    return tuple(DesignPoint(m, int(c * 2**20),
                             group=float(c) if single else (nd.name, float(c)),
                             node=nd)
                 for nd in nodes for c in capacities_mb for m in mems)


def design_corners(points: Sequence[tuple[str, float]],
                   group: object = 0) -> tuple[DesignPoint, ...]:
    """Explicit (mem, capacity_mb) corners sharing one normalization group
    — the iso-area design axis (different capacities, one SRAM baseline)."""
    return tuple(DesignPoint(m, int(c * 2**20), group=group)
                 for m, c in points)


def workload_scenarios(workloads: Mapping[str, Workload] | Iterable[Workload],
                       stages: Sequence[tuple[bool, int]],
                       stage_major: bool = False,
                       ) -> tuple[TrafficStats, ...]:
    """Scenario axis of a (workload x stage) grid, via the shared memoized
    ``workload_engine.stats_for``.  ``stages`` are (training, batch) pairs;
    ``stage_major`` controls the row-major axis (scaling iterates stages
    outermost, iso-capacity/iso-area iterate workloads outermost)."""
    items = tuple(workloads.values() if isinstance(workloads, Mapping)
                  else workloads)
    if stage_major:
        return tuple(workload_engine.stats_for(w, batch, training)
                     for training, batch in stages for w in items)
    return tuple(workload_engine.stats_for(w, batch, training)
                 for w in items for training, batch in stages)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative cross-layer sweep: scenarios x designs x platforms."""

    scenarios: tuple[TrafficStats, ...]
    designs: tuple[DesignPoint, ...]
    platforms: tuple[Platform, ...] = (GTX_1080TI,)
    baseline_mem: str = BASELINE_MEM
    name: str = "sweep"

    def __post_init__(self) -> None:
        if not (self.scenarios and self.designs and self.platforms):
            raise ValueError(f"{self.name}: every axis must be non-empty")
        keys = [(s.workload, s.batch, s.training) for s in self.scenarios]
        if len(set(keys)) != len(keys):
            raise ValueError(f"{self.name}: duplicate scenario keys")
        if len(set(self.designs)) != len(self.designs):
            raise ValueError(f"{self.name}: duplicate design points")

    def run(self) -> SweepResult:
        return run(self)


# ---------------------------------------------------------------------------
# Lowering: spec -> one circuit call + one workload-fold call
# ---------------------------------------------------------------------------


def lower_designs(points: Sequence[DesignPoint],
                  ) -> tuple[engine.DesignTable, tuple[CacheDesign, ...]]:
    """One memoized ``engine.design_table`` over the unique nodes, mems,
    and capacities, then the EDAP-tuned design of every point (Algorithm 1,
    memoized per (node, mem, capacity) on the table)."""
    nodes = tuple(dict.fromkeys(p.node for p in points))
    mems = tuple(dict.fromkeys(p.mem for p in points))
    caps = tuple(dict.fromkeys(p.capacity_bytes for p in points))
    table = engine.design_table(mems, caps, nodes=nodes)
    return table, tuple(table.tuned(p.mem, p.capacity_bytes, node=p.node)
                        for p in points)


@functools.lru_cache(maxsize=None)
def _run_cached(spec: SweepSpec) -> SweepResult:
    table, designs = lower_designs(spec.designs)
    tables = workload_engine.evaluate_platforms(spec.scenarios, designs,
                                                spec.platforms)
    return SweepResult(spec=spec, design_table=table, designs=designs,
                       tables=tables)


def run(spec: SweepSpec) -> SweepResult:
    """Lower and evaluate a spec: exactly one ``engine.design_table`` call
    plus one ``workload_engine.evaluate_platforms`` call.  Memoized per
    spec, so equal specs share one SweepResult object."""
    return _run_cached(spec)


def clear_cache() -> None:
    """Drop memoized sweep results (benchmark reruns; the engine-layer
    caches are cleared separately via their own hooks)."""
    _run_cached.cache_clear()


# ---------------------------------------------------------------------------
# Result: labeled axes + tidy views
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class SweepResult:
    """Evaluated sweep: [platform] x [scenario] x [design] tensors.

    ``tables[i]`` is the WorkloadTable view of platform i (one shared
    kernel evaluation); ``design_table`` is the circuit-engine sweep the
    designs were tuned from.
    """

    spec: SweepSpec
    design_table: engine.DesignTable
    designs: tuple[CacheDesign, ...]
    tables: tuple[workload_engine.WorkloadTable, ...]

    # -- labeled axes ------------------------------------------------------

    @property
    def scenario_labels(self) -> tuple[tuple[str, int, bool], ...]:
        """(workload, batch, training) per scenario row."""
        return self.tables[0].scenarios

    @property
    def design_labels(self) -> tuple[tuple[str, float, str], ...]:
        """(mem, capacity_mb, node_name) per design column."""
        return tuple((p.mem, p.capacity_mb, p.node.name)
                     for p in self.spec.designs)

    @property
    def platform_labels(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.spec.platforms)

    @property
    def axes(self) -> dict[str, tuple]:
        return {"platform": self.platform_labels,
                "scenario": self.scenario_labels,
                "design": self.design_labels}

    def design_index(self, mem: str, capacity_mb: float | None = None,
                     node: TechNode | str | None = None) -> int:
        node_name = node.name if isinstance(node, TechNode) else node
        matches = [j for j, p in enumerate(self.spec.designs)
                   if p.mem == mem
                   and capacity_mb in (None, p.capacity_mb)
                   and node_name in (None, p.node.name)]
        if not matches:
            raise ValueError(
                f"no design ({mem}, {capacity_mb}, {node_name}) in sweep")
        if len(matches) > 1:
            raise ValueError(
                f"ambiguous design ({mem}, {capacity_mb}, {node_name})")
        return matches[0]

    # -- metric tensors ----------------------------------------------------

    def metric(self, name: str, include_dram: bool = False) -> np.ndarray:
        """[p, s, d] tensor of one METRICS entry."""
        return np.stack([t.metric(name, include_dram) for t in self.tables])

    @property
    def dram_tx(self) -> np.ndarray:
        """[s, d] DRAM transactions (platform-independent)."""
        return self.tables[0].dram_tx

    @property
    def read_write_ratio(self) -> np.ndarray:
        """[s] L2 read/write transaction ratio (platform-independent)."""
        return self.tables[0].read_write_ratio

    # -- normalization (the paper's figure convention) ---------------------

    def baseline_indices(self, baseline_mem: str | None = None) -> np.ndarray:
        """[d] index of each design's normalization baseline: the unique
        baseline-memory design of its group."""
        base = baseline_mem if baseline_mem is not None \
            else self.spec.baseline_mem
        by_group: dict[object, int] = {}
        for j, p in enumerate(self.spec.designs):
            if p.mem == base:
                if p.group in by_group:
                    raise ValueError(
                        f"group {p.group!r} has several {base!r} designs")
                by_group[p.group] = j
        missing = {p.group for p in self.spec.designs} - set(by_group)
        if missing:
            raise ValueError(f"groups {sorted(map(repr, missing))} have no "
                             f"{base!r} baseline design")
        return np.array([by_group[p.group] for p in self.spec.designs])

    def norm_to(self, baseline_mem: str | None = None) -> NormalizedSweep:
        """Metrics normalized to the baseline design of each group."""
        return NormalizedSweep(self, self.baseline_indices(baseline_mem))

    # -- tidy materialization ----------------------------------------------

    def rows(self, include_norm: bool = True,
             include_dram: bool = False) -> list[dict]:
        """Long-format rows: one dict per (platform, scenario, design)."""
        m = {name: self.metric(name, include_dram) for name in METRICS}
        norm = self.norm_to() if include_norm else None
        x = {name: norm.metric(name, include_dram)
             for name in METRICS} if include_norm else {}
        out = []
        for pi, platform in enumerate(self.platform_labels):
            for si, (workload, batch, training) in \
                    enumerate(self.scenario_labels):
                for di, point in enumerate(self.spec.designs):
                    row = dict(platform=platform, workload=workload,
                               batch=batch,
                               stage="train" if training else "infer",
                               mem=point.mem,
                               capacity_mb=point.capacity_mb,
                               node=point.node.name,
                               group=point.group)
                    row.update({_ROW_FIELD[k]: float(v[pi, si, di])
                                for k, v in m.items()})
                    row.update({f"{k}_x": float(v[pi, si, di])
                                for k, v in x.items()})
                    out.append(row)
        return out

    def summary(self, include_dram: bool = True) -> dict:
        """Per-(platform, non-baseline mem) aggregate reductions over all
        scenarios and design groups (the §IV prose-claim shape)."""
        norm = self.norm_to()
        energy = norm.metric("energy", include_dram=False)
        edp = norm.metric("edp", include_dram=include_dram)
        dyn = norm.metric("dyn")
        leak = norm.metric("leak")
        base = self.baseline_indices()
        out: dict[str, dict[str, dict[str, float]]] = {}
        for pi, platform in enumerate(self.platform_labels):
            per_mem: dict[str, dict[str, float]] = {}
            for mem in dict.fromkeys(p.mem for p in self.spec.designs):
                if mem == self.spec.baseline_mem:
                    continue
                cols = [j for j, p in enumerate(self.spec.designs)
                        if p.mem == mem and base[j] != j]
                if not cols:
                    continue
                per_mem[mem] = dict(
                    dyn_energy_x=float(dyn[pi][:, cols].mean()),
                    leak_reduction=float((1.0 / leak[pi][:, cols]).mean()),
                    energy_reduction=float(
                        (1.0 / energy[pi][:, cols]).mean()),
                    edp_reduction_mean=float((1.0 / edp[pi][:, cols]).mean()),
                    edp_reduction_max=float((1.0 / edp[pi][:, cols]).max()),
                )
            out[platform] = per_mem
        return out

    def to_csv(self, path: str, include_norm: bool = True,
               include_dram: bool = False) -> None:
        report.write_csv(path, self.rows(include_norm, include_dram))


@dataclasses.dataclass(frozen=True, eq=False)
class NormalizedSweep:
    """View of a SweepResult with every metric divided by its group's
    baseline design (elementwise, the scalar IsoCapRow.norm convention)."""

    result: SweepResult
    baseline: np.ndarray  # [d] baseline design index per design

    def metric(self, name: str, include_dram: bool = False) -> np.ndarray:
        m = self.result.metric(name, include_dram)
        return m / m[:, :, self.baseline]

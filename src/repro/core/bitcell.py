"""Bitcell characterization — reproduces paper Table I.

The paper's circuit-level flow (§III-A): parametrized SPICE netlists where
read/write pulse widths are modulated to the point of failure, sweeping the
access-device fin count to find the optimal latency/energy/area balance.

Our equivalent: analytic MTJ switching models (core/mtj.py) + a fin-count
sweep under real layout feasibility constraints:

  * A 2-poly-pitch MRAM bitcell accommodates at most MAX_FINS=4 fins total
    (the bitcell-area formulation of Seo & Roy [45] that the paper uses).
  * STT shares one access transistor between read and write paths, so all
    fins serve both; the write current must exceed the MTJ critical current
    (feasibility), and reads are capped by the short-pulse read-disturb
    ceiling (wordline under-drive).
  * SOT has decoupled read/write devices; both need >= 1 fin within the
    same 4-fin budget, and the write path must exceed Ic0 of the SOT line.

The sweep minimizes a bitcell-level EDAP metric over feasible assignments.
Outcomes (validated in tests/benchmarks against Table I): STT -> 4 shared
fins; SOT -> 3 write + 1 read fins — feasibility alone forces both, which
matches the paper's chosen design points.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import mtj, tech
from repro.core.tech import TechNode, TECH_16NM

MAX_FINS = 4  # 2-poly-pitch bitcell fin budget ([45] layout formulation)

# Bitcell parameters consumed by the cache PPA equations, in the order the
# batched engine (core/engine.py) packs them into per-technology vectors.
ARRAY_FIELDS = (
    "read_current_a",
    "sense_latency_s",
    "sense_energy_j",
    "write_latency_avg_s",
    "write_energy_avg_j",
    "area_norm",
    "cell_leakage_w",
)

# Bitcell footprint vs fin count, normalized to the foundry 6T SRAM cell,
# at the 16 nm anchor.  Linear-in-fins with a per-structure base term
# ([45]); SOT's shared-bitline structure has the smaller base despite its
# second device.  Across nodes the base term (MTJ pillar + BEOL keep-out,
# via/metal-pitch limited) shrinks slower than the 6T footprint while the
# fin term (front-end devices) tracks it — tech.BITCELL_SCALING_EXPONENTS.
_AREA_BASE = {"stt": 0.10, "sot": 0.05}
_AREA_PER_FIN = 0.06

# Read-path current per fin at the 16 nm anchor.  Writes drive the full
# I_on; reads are derated: STT under-drives the read wordline to respect
# the read-disturb ceiling, SOT's read current is series-limited by the MTJ
# stack resistance.  Both MRAM access paths derate with the supply at
# scaled nodes (i_read/i_write_per_fin exponents).
_I_READ_PER_FIN = {"stt": 42e-6, "sot": 38.5e-6}
# Short-pulse (650 ps << thermal switching time) read-disturb ceiling for
# shared-path STT reads: 1.05x the smaller critical current.
_STT_READ_CAP_FRAC = 1.05

# Intrinsic 6T read/write time and ~fJ/bit bitline swing energy at 16 nm
# (sram_bitcell anchors; CV/I and CV^2 node scaling).
_SRAM_T_RW = 120e-12
_SRAM_E_RW = 1.3e-15


def _bitcell_scale(name: str, node: TechNode) -> float:
    """s**exp factor of one bitcell-level quantity at ``node`` (exactly 1.0
    at the 16 nm anchor)."""
    return tech.scale_factor(node) ** tech.BITCELL_SCALING_EXPONENTS[name]


@dataclasses.dataclass(frozen=True)
class Bitcell:
    """Characterized bitcell — the rows of paper Table I."""

    name: str
    sense_latency_s: float
    sense_energy_j: float
    write_latency_set_s: float
    write_latency_reset_s: float
    write_energy_set_j: float
    write_energy_reset_j: float
    fins_read: int
    fins_write: int
    area_norm: float            # normalized to foundry SRAM bitcell
    cell_leakage_w: float       # storage-cell leakage (0 for MRAM cores)
    read_current_a: float

    @property
    def write_latency_avg_s(self) -> float:
        return 0.5 * (self.write_latency_set_s + self.write_latency_reset_s)

    @property
    def write_energy_avg_j(self) -> float:
        return 0.5 * (self.write_energy_set_j + self.write_energy_reset_j)

    @property
    def shares_access_device(self) -> bool:
        return self.name == "stt"

    def as_array(self) -> np.ndarray:
        """Parameter vector (float64, ARRAY_FIELDS order) for the batched
        engine: one row of the per-technology parameter matrix."""
        return np.array([getattr(self, f) for f in ARRAY_FIELDS],
                        dtype=np.float64)


def _read_current(tech_name: str, dev: mtj.MTJDevice, node: TechNode,
                  fins: int) -> float:
    i = fins * _I_READ_PER_FIN[tech_name] * _bitcell_scale("i_read_per_fin",
                                                           node)
    if tech_name == "stt":
        # Reads use the set-polarity current direction, so the short-pulse
        # disturb ceiling is referenced to Ic0(set).
        i = min(i, _STT_READ_CAP_FRAC * dev.ic0_set_a)
    return i


def _write_current(node: TechNode, fins_write: int) -> float:
    """MRAM write-path drive: full per-fin I_on derated by the node's
    write-path headroom factor (tech.BITCELL_SCALING_EXPONENTS)."""
    return fins_write * node.ion_per_fin_a \
        * _bitcell_scale("i_write_per_fin", node)


def base_area_norm(tech_name: str, node: TechNode = TECH_16NM) -> float:
    """The fin-independent bitcell footprint term (MTJ pillar + BEOL
    keep-out, normalized to the foundry 6T cell) at ``node`` — the anchor
    value every ``area_base_norm`` override (inverse-design leaf) is
    centered on."""
    return _AREA_BASE[tech_name] * _bitcell_scale("area_base", node)


def fin_assignments(tech_name: str) -> tuple[tuple[int, int, bool], ...]:
    """The full layout-feasible ``(fins_read, fins_write, shared)`` grid the
    characterization sweep enumerates: STT shares one access device across
    both paths (1..MAX_FINS shared fins); SOT decouples them, each path
    needs >= 1 fin, and the pair fits the same MAX_FINS budget.  Static —
    the inverse path's softmin relaxes over exactly this tuple."""
    if tech_name == "stt":
        return tuple((f, f, True) for f in range(1, MAX_FINS + 1))
    if tech_name == "sot":
        return tuple((fr, fw, False)
                     for fr in range(1, MAX_FINS)
                     for fw in range(1, MAX_FINS)
                     if fr + fw <= MAX_FINS)
    raise ValueError(f"no fin sweep for tech {tech_name!r}")


def assemble(tech_name: str, node: TechNode, fins_read: int, fins_write: int,
             shared: bool, *, device: mtj.MTJDevice | None = None,
             area_base_norm: float | None = None) -> Bitcell | None:
    """Assemble one explicit fin assignment into a :class:`Bitcell`
    (None if infeasible) — the standard-path re-evaluation entry for
    inverse design: ``device`` substitutes a :func:`mtj.custom_device`
    with converged leaves and ``area_base_norm`` overrides the
    fin-independent footprint term (default :func:`base_area_norm`)."""
    dev = mtj.device(tech_name, node) if device is None else device
    return _evaluate(tech_name, dev, node, fins_read, fins_write, shared,
                     area_base_norm=area_base_norm)


def _evaluate(tech_name: str, dev: mtj.MTJDevice, node: TechNode,
              fins_read: int, fins_write: int, shared: bool,
              area_base_norm: float | None = None) -> Bitcell | None:
    """Evaluate one fin assignment; None if infeasible."""
    total_fins = fins_write if shared else fins_read + fins_write
    if total_fins > MAX_FINS or fins_read < 1 or fins_write < 1:
        return None
    i_write = _write_current(node, fins_write)
    t_set = mtj.switching_time(dev, i_write, reset=False)
    t_reset = mtj.switching_time(dev, i_write, reset=True)
    if not (math.isfinite(t_set) and math.isfinite(t_reset)):
        return None  # below critical current: write never completes
    i_read = _read_current(tech_name, dev, node, fins_read)
    if area_base_norm is None:
        area_base_norm = base_area_norm(tech_name, node)
    return Bitcell(
        name=tech_name,
        sense_latency_s=dev.sense_time_s,
        sense_energy_j=mtj.sense_energy(dev, i_read, node.vdd_v),
        write_latency_set_s=t_set,
        write_latency_reset_s=t_reset,
        write_energy_set_j=mtj.switching_energy(dev, i_write, reset=False),
        write_energy_reset_j=mtj.switching_energy(dev, i_write, reset=True),
        fins_read=fins_read,
        fins_write=fins_write,
        area_norm=area_base_norm
        + _AREA_PER_FIN * _bitcell_scale("area_per_fin", node) * total_fins,
        cell_leakage_w=total_fins * node.ioff_per_fin_a * node.vdd_v,
        read_current_a=i_read,
    )


def _edap(cell: Bitcell) -> float:
    """Bitcell-level energy-delay-area objective for the fin sweep."""
    ed = (cell.sense_latency_s * cell.sense_energy_j
          + cell.write_latency_avg_s * cell.write_energy_avg_j)
    return ed * cell.area_norm


def characterize(tech_name: str, node: TechNode = TECH_16NM) -> Bitcell:
    """Fin-count sweep (paper §III-A) -> EDAP-optimal bitcell.

    The sweep runs on the node-projected device (``mtj.device``) with
    node-derated drive currents, so a scaled node re-characterizes the
    bitcell on genuinely scaled physics.  If no fin assignment's write
    current clears the device's critical current — the STT scaling wall at
    deep nodes, where drive derates faster than the retention-pinned Ic0 —
    the raised diagnostic says exactly how far short the best drive falls.
    """
    if tech_name == "sram":
        return sram_bitcell(node)
    dev = mtj.device(tech_name, node)
    assignments = fin_assignments(tech_name)
    candidates = [cell for fr, fw, shared in assignments
                  if (cell := _evaluate(tech_name, dev, node, fr, fw,
                                        shared)) is not None]
    max_write_fins = max(fw for _, fw, _ in assignments)
    if not candidates:
        best_i = _write_current(node, max_write_fins)
        ic0 = max(dev.ic0_set_a, dev.ic0_reset_a)
        raise ValueError(
            f"no feasible {tech_name} bitcell at node {node.name!r}: the "
            f"best available write current ({max_write_fins} fins -> "
            f"{best_i * 1e6:.1f} uA) does not exceed the device critical "
            f"current (Ic0 = {ic0 * 1e6:.1f} uA) — the node's drive derates "
            "below the switching threshold (see "
            "tech.BITCELL_SCALING_EXPONENTS / tech.MTJ_SCALING_EXPONENTS)")
    return min(candidates, key=_edap)


def sram_bitcell(node: TechNode = TECH_16NM) -> Bitcell:
    """Foundry 6T SRAM bitcell (the Table I normalization baseline).

    SRAM has no MTJ: reads/writes are bitline (dis)charge events, fast and
    symmetric; the storage cell itself leaks continuously (the scalability
    problem the paper targets).  Cell leakage comes from the node:
    ``TechNode.sram_cell_leak_w`` is calibrated at the 16 nm anchor so the
    3 MB EDAP-tuned cache reproduces Table II's 6442 mW, and scaled nodes
    carry their own (worsening) projection — the cross-node SRAM leakage
    trend the DTCO analysis reads.  The intrinsic 6T access time and energy
    scale with the node too (CV/I and CV^2 rules,
    tech.BITCELL_SCALING_EXPONENTS).
    """
    t_rw = _SRAM_T_RW * _bitcell_scale("sram_t_rw", node)
    e_rw = _SRAM_E_RW * _bitcell_scale("sram_e_rw", node)
    return Bitcell(
        name="sram",
        sense_latency_s=t_rw,
        sense_energy_j=e_rw,
        write_latency_set_s=t_rw,
        write_latency_reset_s=t_rw,
        write_energy_set_j=e_rw,
        write_energy_reset_j=e_rw,
        fins_read=2,
        fins_write=2,
        area_norm=1.0,
        cell_leakage_w=node.sram_cell_leak_w,
        read_current_a=2 * node.ion_per_fin_a,
    )


def table1() -> dict[str, Bitcell]:
    """All three characterized bitcells (paper Table I + SRAM baseline)."""
    return {name: characterize(name) for name in ("sram", "stt", "sot")}

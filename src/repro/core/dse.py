"""Pareto / DSE reductions over evaluated sweeps.

The multi-capacity sweeps (scaling, nvm_dse, DTCO) produce a design axis
far wider than the paper's three iso-capacity columns; this module reduces
an evaluated :class:`~repro.core.sweep.SweepResult` to the decisions a DSE
flow actually wants:

  * ``pareto_front`` — per (platform, scenario), the non-dominated designs
    over a set of minimize-objectives (default energy / runtime / area:
    the EDAP axes Algorithm 1 trades off, now across the whole design
    axis rather than within one (mem, capacity) organization sweep).
  * ``capacity_plateaus`` — per (platform, scenario, mem, node), the
    capacity knee: the smallest capacity whose metric is within
    ``rel_tol`` of the best along the capacity axis.  Beyond it, more
    on-chip memory buys less than ``rel_tol`` — the Fig. 9/10 "leakage
    eats the capacity win" argument reduced to one number per memory.

Everything here is a pure reduction of the result tensors (numpy only —
no engine calls, no sweep imports; the result object is duck-typed), so
the query layer stays cycle-free below core/sweep.py.
"""

from __future__ import annotations

from collections.abc import Sequence

import numpy as np

# Objectives are SweepResult.metric names plus "area" (a design attribute,
# broadcast over platforms and scenarios).  All are minimized.
DEFAULT_OBJECTIVES = ("energy", "runtime", "area")


def objective_tensor(result, name: str,
                     include_dram: bool = False) -> np.ndarray:
    """[p, s, d] tensor of one objective (metrics via the result's metric
    vocabulary; "area" from the tuned designs)."""
    if name == "area":
        area = np.array([d.area_mm2 for d in result.designs],
                        dtype=np.float64)
        shape = (len(result.platform_labels), len(result.scenario_labels),
                 area.size)
        return np.broadcast_to(area, shape)
    return result.metric(name, include_dram)


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """[n] mask of the non-dominated rows of an [n, k] objective matrix
    (minimization; a point is dominated when some other point is <= on
    every objective and < on at least one)."""
    pts = np.asarray(points, dtype=np.float64)
    le = (pts[:, None, :] <= pts[None, :, :]).all(axis=2)   # [i, j]: i <= j
    lt = (pts[:, None, :] < pts[None, :, :]).any(axis=2)
    dominated = (le & lt).any(axis=0)                       # some i beats j
    return ~dominated


def pareto_front(result, objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                 include_dram: bool = False) -> list[dict]:
    """Non-dominated designs per (platform, scenario) cell, as tidy rows
    (axis labels + the objective values + the cell's front size)."""
    objectives = tuple(objectives)
    tensors = [objective_tensor(result, o, include_dram) for o in objectives]
    rows = []
    for pi, platform in enumerate(result.platform_labels):
        for si in range(len(result.scenario_labels)):
            pts = np.stack([t[pi, si, :] for t in tensors], axis=1)
            mask = pareto_mask(pts)
            front = np.flatnonzero(mask)
            for di in front:
                rows.append(dict(platform=platform,
                                 **result.scenario_attrs(si),
                                 **result.design_attrs(int(di)),
                                 design_index=int(di),
                                 front_size=int(front.size),
                                 **{o: float(pts[di, k])
                                    for k, o in enumerate(objectives)}))
    return rows


def capacity_plateaus(result, metric: str = "edp",
                      include_dram: bool = True,
                      rel_tol: float = 0.05) -> list[dict]:
    """Capacity-plateau detection along the design axis.

    For every (mem, node) that appears at two or more capacities, and for
    every (platform, scenario): sort the capacities, find the best metric
    value along the axis, and report the smallest capacity within
    ``rel_tol`` of it.  ``plateau_penalty`` is the relative distance of
    the plateau point from the best (0 when the plateau IS the best)."""
    t = objective_tensor(result, metric, include_dram)
    by_mem_node: dict[tuple[str, str], list[tuple[float, int]]] = {}
    for j, p in enumerate(result.spec.designs):
        by_mem_node.setdefault((p.mem, p.node.name), []).append(
            (p.capacity_mb, j))
    rows = []
    for (mem, node), caps in by_mem_node.items():
        if len(caps) < 2:
            continue
        caps = sorted(caps)
        cap_axis = [c for c, _ in caps]
        ids = [j for _, j in caps]
        for pi, platform in enumerate(result.platform_labels):
            for si in range(len(result.scenario_labels)):
                v = t[pi, si, ids]
                best_i = int(v.argmin())
                within = np.flatnonzero(v <= v[best_i] * (1.0 + rel_tol))
                plateau_i = int(within[0])
                rows.append(dict(platform=platform,
                                 **result.scenario_attrs(si),
                                 mem=mem, node=node,
                                 plateau_capacity_mb=cap_axis[plateau_i],
                                 best_capacity_mb=cap_axis[best_i],
                                 plateau_penalty=float(
                                     v[plateau_i] / v[best_i] - 1.0)))
    return rows

"""Batched workload-evaluation engine — the architecture-layer fold as one
tensor computation.

core/engine.py batches the circuit layer (the NVSim tech x capacity x
organization sweep); this module batches the layer DeepNVM++ stacks on top
of it: folding workload memory traffic through tuned cache designs to get
runtime, dynamic/leakage/DRAM energy, and EDP (paper Figs. 3-10).  The
scalar path (``traffic.runtime`` / ``traffic.energy``, one call per
(workload, memory, capacity)) survives as the parity reference, pinned by
tests/test_workload_engine.py to a few ulps.

Representation: structure-of-arrays, padded.  Every scenario — one
``TrafficStats``, i.e. one (workload, batch, training) execution — packs
its ``AccessStream`` tuple into rows of four [scenario, stream] tensors
(``bytes_total``, ``is_write``, ``reuse_distance``, ``dram_visible``) with
a stream-count ``mask`` marking real entries (padding rows carry zero
bytes, infinite reuse distance, and a False mask, so they contribute
nothing to any fold).  Designs — (memory, capacity) points read from
``engine.DesignTable`` — pack into five [design] vectors.  One jitted
float64 kernel then evaluates the full cross product

    [scenario] x [design]  ->  runtime / energy / EDP tensors [s, d]

reproducing the scalar path's operation order exactly: the miss-curve
``dram_tx`` fold, the \"simple model\" runtime (compute + serialized L2 +
DRAM stall), and the dynamic/leakage/DRAM energy terms.

The platform is itself a batched axis: ``evaluate_platforms`` evaluates

    [platform] x [scenario] x [design]

in one kernel call (platform parameters are a [p, 4] runtime input, so
e.g. GTX_1080TI vs TPU_V5E share one trace), returning one
:class:`WorkloadTable` view per platform.  Platform-independent tensors
(L2 transactions, DRAM transactions, dynamic energy) are computed once
and shared across the views.

:class:`WorkloadTable` wraps the result tensors with the same vocabulary
the scalar API uses (``total_j``/``edp``/``EnergyReport``), and
``evaluate`` memoizes tables per (scenarios, designs, platforms) so the
iso-capacity, iso-area, and scaling analyses plus the benchmarks all share
one evaluation — the whole cross-layer pipeline becomes two composed
batched computations (circuit sweep, workload fold).
"""

from __future__ import annotations

import dataclasses
import functools
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import traffic
from repro.core.cachemodel import LINE_BYTES, CacheDesign
from repro.core.tech import Platform, GTX_1080TI
from repro.core.traffic import (
    ASSOC_EFFICIENCY,
    COMPUTE_EFFICIENCY,
    MISS_CURVE_P,
    EnergyReport,
    TrafficStats,
)
from repro.core.workloads import Workload

# Platform parameters consumed by the fold, in the order they are packed
# into the platform vector (a runtime input, so a different platform —
# e.g. TPU_V5E — does not recompile the kernel).
PLATFORM_FIELDS = ("peak_flops", "mem_serialization", "dram_bw",
                   "dram_energy_per_byte")

@functools.lru_cache(maxsize=None)
def stats_for(workload: Workload, batch: int, training: bool) -> TrafficStats:
    """Memoized ``traffic.build`` — scenarios are shared across analyses."""
    return traffic.build(workload, batch, training)


# ---------------------------------------------------------------------------
# Packing: AccessStreams -> padded SoA tensors, designs -> vectors
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class StreamBatch:
    """Padded structure-of-arrays pack of many scenarios' AccessStreams."""

    keys: tuple[tuple[str, int, bool], ...]  # (workload, batch, training)
    bytes_total: np.ndarray     # [s, k] float64, padded 0.0
    is_write: np.ndarray        # [s, k] bool,    padded False
    reuse_distance: np.ndarray  # [s, k] float64, padded inf
    dram_visible: np.ndarray    # [s, k] bool,    padded False
    mask: np.ndarray            # [s, k] bool — True on real streams
    macs: np.ndarray            # [s] float64


def pad_width(k: int) -> int:
    """Pack-width bucket: the next power of two >= k (minimum 8).

    The chunked sweep path pads each chunk to a bucket instead of its
    exact stream-count maximum, so chunks with nearby widths share one
    compiled fold kernel; relative padding waste stays < 2x while the
    number of distinct kernel shapes stays O(log max_k)."""
    if k < 1:
        raise ValueError("pad_width needs k >= 1")
    w = 8
    while w < k:
        w *= 2
    return w


# Scenario/design axis-bucket floors of the bucketed (service) fold path:
# requests below the floor share the floor's compiled shape, so tiny specs
# don't each pin their own trace.
S_BUCKET_FLOOR = 4
D_BUCKET_FLOOR = 4


def axis_bucket(n: int, floor: int = 1) -> int:
    """Batch-axis shape bucket: the next power of two >= max(n, floor).

    The ``pad_width`` idea generalized to the scenario and design axes —
    the bucketed fold pads every axis to its bucket, so the set of
    compiled kernel shapes stays O(log^3) over arbitrary request sizes
    (the property that makes ``warmup`` able to pre-trace them all)."""
    if n < 1:
        raise ValueError("axis_bucket needs n >= 1")
    w = max(1, floor)
    while w < n:
        w *= 2
    return w


def pack(stats_seq: Sequence[TrafficStats],
         width: int | None = None) -> StreamBatch:
    """Pack scenarios into padded [scenario, stream] tensors.

    ``width`` overrides the padded stream-axis size (default: the max
    stream count across *these* scenarios).  The sharded sweep path packs
    per chunk — so one outlier scenario (e.g. googlenet train, 645
    streams) widens only its own chunk, not every chunk of the sweep; a
    global pack pads every scenario row to the global max and is the
    memory blowup that makes mixed mega-specs OOM earlier than cell count
    alone predicts.  Padding rows carry zero bytes, infinite reuse
    distance, and a False mask, so any width gives the same fold result.
    """
    stats_seq = tuple(stats_seq)
    k = max(len(s.streams) for s in stats_seq)
    if width is not None:
        if width < k:
            raise ValueError(f"width {width} < max stream count {k}")
        k = width
    n = len(stats_seq)
    bytes_total = np.zeros((n, k), dtype=np.float64)
    is_write = np.zeros((n, k), dtype=bool)
    reuse = np.full((n, k), np.inf, dtype=np.float64)
    visible = np.zeros((n, k), dtype=bool)
    mask = np.zeros((n, k), dtype=bool)
    for i, stats in enumerate(stats_seq):
        a = stats._arrays
        m = len(stats.streams)
        bytes_total[i, :m] = a["bytes_total"]
        is_write[i, :m] = a["is_write"]
        reuse[i, :m] = a["reuse_distance"]
        visible[i, :m] = a["dram_visible"]
        mask[i, :m] = True
    return StreamBatch(
        keys=tuple((s.workload, s.batch, s.training) for s in stats_seq),
        bytes_total=bytes_total, is_write=is_write, reuse_distance=reuse,
        dram_visible=visible, mask=mask,
        macs=np.array([s.macs_per_batch for s in stats_seq],
                      dtype=np.float64),
    )


def _design_vectors(designs: Sequence[CacheDesign]) -> tuple[np.ndarray, ...]:
    def as_vec(field: str) -> np.ndarray:
        return np.array([getattr(d, field) for d in designs], dtype=np.float64)

    return (as_vec("read_latency_s"), as_vec("write_latency_s"),
            as_vec("read_energy_j"), as_vec("write_energy_j"),
            as_vec("leakage_w"), as_vec("capacity_bytes"))


def _platform_vector(platform: Platform) -> np.ndarray:
    return np.array([getattr(platform, f) for f in PLATFORM_FIELDS],
                    dtype=np.float64)


# ---------------------------------------------------------------------------
# The jitted fold
# ---------------------------------------------------------------------------


def _miss_tx(bytes_total, rd, visible, caps):
    """[s, c] DRAM transactions — TrafficStats.dram_tx's fold, batched.

    Each stream misses with probability (RD / (RD + C_eff))^MISS_CURVE_P
    (RD=inf always misses); only DRAM-visible streams count.
    """
    c_eff = caps * ASSOC_EFFICIENCY                       # [c]
    r = rd[:, None, :]                                    # [s, 1, k]
    ratio = r / (r + c_eff[None, :, None])
    miss_p = jnp.where(jnp.isinf(r), 1.0, ratio ** MISS_CURVE_P)
    tx = bytes_total[:, None, :] / LINE_BYTES * miss_p
    return jnp.where(visible[:, None, :], tx, 0.0).sum(axis=2)


_miss_tx_kernel = jax.jit(_miss_tx)


def _fold(bytes_total, is_write, rd, visible, mask, macs,
          rl, wl, re_, we_, leak, caps, pmat):
    """The full [platform] x [scenario] x [design] workload fold.

    Streams [s, k], designs [d], platforms [p, 4] -> platform-dependent
    metric tensors [p, s, d] plus platform-independent [s] / [s, d] ones.
    Every expression keeps the scalar traffic.runtime/energy operation
    order so float64 results match the Python reference to the last ulps.
    """
    peak_flops = pmat[:, 0][:, None, None]       # [p, 1, 1]
    serialization = pmat[:, 1][:, None, None]
    dram_bw = pmat[:, 2][:, None, None]
    dram_epb = pmat[:, 3][:, None, None]
    bt = jnp.where(mask, bytes_total, 0.0)
    read_tx = jnp.where(is_write, 0.0, bt).sum(axis=1) / LINE_BYTES   # [s]
    write_tx = jnp.where(is_write, bt, 0.0).sum(axis=1) / LINE_BYTES
    dram_tx = _miss_tx(bt, rd, visible & mask, caps)                  # [s, d]

    t_compute = macs[None, :, None] * 2.0 \
        / (peak_flops * COMPUTE_EFFICIENCY)                           # [p, s, 1]
    t_l2 = read_tx[:, None] * rl[None, :] + write_tx[:, None] * wl[None, :]
    runtime_nodram = t_compute + serialization * t_l2[None]           # [p, s, d]
    runtime = runtime_nodram + (dram_tx * LINE_BYTES)[None] / dram_bw

    return dict(
        l2_read_tx=read_tx,
        l2_write_tx=write_tx,
        dram_tx=dram_tx,
        runtime_s=runtime,
        runtime_nodram_s=runtime_nodram,
        dyn_read_j=read_tx[:, None] * re_[None, :],
        dyn_write_j=write_tx[:, None] * we_[None, :],
        leak_j=leak[None, None, :] * runtime,
        leak_nodram_j=leak[None, None, :] * runtime_nodram,
        dram_j=(dram_tx * LINE_BYTES)[None] * dram_epb,
    )


_fold_kernel = jax.jit(_fold)


# ---------------------------------------------------------------------------
# Result table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class WorkloadTable:
    """Evaluated [scenario] x [design] workload fold.

    Scenario axis: (workload, batch, training) keys in pack order.  Design
    axis: the CacheDesign points (typically EDAP-tuned reads of an
    ``engine.DesignTable``).  ``runtime_s``/``leak_j`` include the DRAM
    stall term (the scalar path's ``include_dram=True`` default); the
    ``*_nodram`` variants mirror ``include_dram=False``.
    """

    scenarios: tuple[tuple[str, int, bool], ...]
    designs: tuple[CacheDesign, ...]
    platform: Platform
    l2_read_tx: np.ndarray      # [s]
    l2_write_tx: np.ndarray     # [s]
    dram_tx: np.ndarray         # [s, d]
    runtime_s: np.ndarray       # [s, d]
    runtime_nodram_s: np.ndarray
    dyn_read_j: np.ndarray
    dyn_write_j: np.ndarray
    leak_j: np.ndarray
    leak_nodram_j: np.ndarray
    dram_j: np.ndarray

    # -- indexing ----------------------------------------------------------

    def scenario_index(self, workload: str, batch: int, training: bool) -> int:
        return self.scenarios.index((workload, batch, training))

    def design_index(self, mem: str, capacity_bytes: int | None = None) -> int:
        matches = [j for j, d in enumerate(self.designs)
                   if d.mem == mem
                   and capacity_bytes in (None, d.capacity_bytes)]
        if not matches:
            raise ValueError(f"no design ({mem}, {capacity_bytes}) in table")
        if len(matches) > 1:
            if capacity_bytes is None:
                raise ValueError(f"{mem!r} appears at several capacities; "
                                 "pass capacity_bytes")
            # duplicate (mem, capacity) designs — e.g. the same corner at
            # two technology nodes — cannot be told apart here; never
            # silently return the first (SweepResult.design_index parity)
            raise ValueError(f"several designs match ({mem}, "
                             f"{capacity_bytes}); look them up by index")
        return matches[0]

    @property
    def read_write_ratio(self) -> np.ndarray:
        return self.l2_read_tx / np.maximum(1.0, self.l2_write_tx)

    # -- derived metric tensors (scalar EnergyReport operation order) ------

    @property
    def dyn_j(self) -> np.ndarray:
        return self.dyn_read_j + self.dyn_write_j

    def total_j(self, include_dram: bool = False) -> np.ndarray:
        total = self.dyn_j + self.leak_j
        return total + self.dram_j if include_dram else total

    def edp(self, include_dram: bool = False) -> np.ndarray:
        return self.total_j(include_dram) * self.runtime_s

    def metric(self, name: str, include_dram: bool = False) -> np.ndarray:
        """[s, d] tensor of one IsoCapRow.norm metric."""
        return {
            "dyn": lambda: self.dyn_j,
            "leak": lambda: self.leak_j,
            "energy": lambda: self.total_j(include_dram),
            "edp": lambda: self.edp(include_dram),
            "runtime": lambda: self.runtime_s,
        }[name]()

    def norm(self, name: str, mem: str, baseline: str = "sram",
             include_dram: bool = False) -> np.ndarray:
        """[s] metric of `mem`'s design normalized to the baseline design
        (the paper's figure convention; designs looked up by memory)."""
        m = self.metric(name, include_dram)
        return m[:, self.design_index(mem)] / m[:, self.design_index(baseline)]

    # -- scalar-API materialization ----------------------------------------

    def report(self, scenario_index: int, design_index: int) -> EnergyReport:
        """One (scenario, design) cell as the scalar-API EnergyReport."""
        s, d = scenario_index, design_index
        return EnergyReport(
            workload=self.scenarios[s][0],
            mem=self.designs[d].mem,
            runtime_s=float(self.runtime_s[s, d]),
            dyn_read_j=float(self.dyn_read_j[s, d]),
            dyn_write_j=float(self.dyn_write_j[s, d]),
            leak_j=float(self.leak_j[s, d]),
            dram_j=float(self.dram_j[s, d]),
        )

    def reports(self, scenario_index: int) -> dict[str, EnergyReport]:
        """All designs of one scenario, keyed by memory technology (the
        IsoCapRow shape — requires memory-unique designs)."""
        out = {d.mem: self.report(scenario_index, j)
               for j, d in enumerate(self.designs)}
        if len(out) != len(self.designs):
            raise ValueError("designs are not memory-unique; key by index")
        return out


# ---------------------------------------------------------------------------
# Evaluation entry points (memoized, like engine.design_table)
# ---------------------------------------------------------------------------


# Result-tensor names that carry a leading platform axis in the kernel
# output; the rest are platform-independent and shared across the views.
_PLATFORM_DEPENDENT = ("runtime_s", "runtime_nodram_s", "leak_j",
                       "leak_nodram_j", "dram_j")


def _tables_from(out: dict, keys, designs, platforms,
                 ) -> tuple[WorkloadTable, ...]:
    """One WorkloadTable view per platform from the fold's output dict."""
    out = {k: np.asarray(v) for k, v in out.items()}
    shared = {k: v for k, v in out.items() if k not in _PLATFORM_DEPENDENT}
    return tuple(
        WorkloadTable(scenarios=keys, designs=designs, platform=p,
                      **shared,
                      **{k: out[k][i] for k in _PLATFORM_DEPENDENT})
        for i, p in enumerate(platforms))


@functools.lru_cache(maxsize=None)
def _evaluate_cached(stats_seq: tuple[TrafficStats, ...],
                     designs: tuple[CacheDesign, ...],
                     platforms: tuple[Platform, ...],
                     ) -> tuple[WorkloadTable, ...]:
    batch = pack(stats_seq)
    rl, wl, re_, we_, leak, caps = _design_vectors(designs)
    pmat = np.stack([_platform_vector(p) for p in platforms])
    with enable_x64():
        out = _fold_kernel(batch.bytes_total, batch.is_write,
                           batch.reuse_distance, batch.dram_visible,
                           batch.mask, batch.macs,
                           rl, wl, re_, we_, leak, caps, pmat)
    return _tables_from(out, batch.keys, designs, platforms)


def evaluate(stats_seq: Sequence[TrafficStats],
             designs: Sequence[CacheDesign],
             platform: Platform = GTX_1080TI) -> WorkloadTable:
    """Evaluate the [scenario] x [design] cross product as one batched
    computation.  Memoized per (scenarios, designs, platforms), so every
    consumer of the same fold shares one kernel invocation."""
    return evaluate_platforms(stats_seq, designs, (platform,))[0]


def evaluate_platforms(stats_seq: Sequence[TrafficStats],
                       designs: Sequence[CacheDesign],
                       platforms: Sequence[Platform] = (GTX_1080TI,),
                       ) -> tuple[WorkloadTable, ...]:
    """Evaluate the full [platform] x [scenario] x [design] cross product
    as one batched kernel call and return one WorkloadTable view per
    platform (platform-independent tensors are shared between views)."""
    return _evaluate_cached(tuple(stats_seq), tuple(designs),
                            tuple(platforms))


# ---------------------------------------------------------------------------
# Chunk-aware evaluation (sharded mega-sweeps, core/sweep.py ShardPlan)
# ---------------------------------------------------------------------------


def evaluate_chunk(stats_seq: Sequence[TrafficStats],
                   designs: Sequence[CacheDesign],
                   platforms: Sequence[Platform] = (GTX_1080TI,),
                   width: int | None = None,
                   ) -> tuple[WorkloadTable, ...]:
    """One chunk of a sharded sweep: like ``evaluate_platforms`` but
    deliberately **uncached** — a mega-sweep evaluates thousands of chunks
    and pinning every chunk's tensors in the lru memo would unbound peak
    memory — and packed to the chunk's own (bucketed) stream width, so an
    outlier-wide scenario inflates only the chunk that contains it."""
    stats_seq = tuple(stats_seq)
    designs = tuple(designs)
    if width is None:
        width = pad_width(max(len(s.streams) for s in stats_seq))
    batch = pack(stats_seq, width=width)
    rl, wl, re_, we_, leak, caps = _design_vectors(designs)
    pmat = np.stack([_platform_vector(p) for p in platforms])
    with enable_x64():
        out = _fold_kernel(batch.bytes_total, batch.is_write,
                           batch.reuse_distance, batch.dram_visible,
                           batch.mask, batch.macs,
                           rl, wl, re_, we_, leak, caps, pmat)
    return _tables_from(out, batch.keys, designs, tuple(platforms))


@functools.lru_cache(maxsize=None)
def _sharded_fold(mesh):
    """The fold, shard_mapped over a 1-D sweep mesh: every input carries a
    leading chunk axis split across devices (the platform matrix is
    replicated), and each device evaluates its chunk independently — the
    fold has no cross-chunk terms, so no collectives are needed."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import SWEEP_AXIS

    sh = P(SWEEP_AXIS)

    def body(bt, iw, rd, vis, mask, macs, rl, wl, re_, we_, leak, caps,
             pmat):
        out = _fold(bt[0], iw[0], rd[0], vis[0], mask[0], macs[0],
                    rl[0], wl[0], re_[0], we_[0], leak[0], caps[0], pmat)
        return {k: v[None] for k, v in out.items()}

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(sh,) * 12 + (P(),),
                             out_specs=sh))


def evaluate_chunk_group(chunk_stats: Sequence[Sequence[TrafficStats]],
                         chunk_designs: Sequence[Sequence[CacheDesign]],
                         platforms: Sequence[Platform],
                         mesh) -> list[tuple[WorkloadTable, ...]]:
    """Evaluate one mesh-width group of same-shaped chunks data-parallel
    across devices via ``shard_map`` (uncached, like ``evaluate_chunk``).

    All chunks must agree on scenario and design counts (the sharded
    lowering groups them so); the group packs to one shared (bucketed)
    stream width.  Returns the per-chunk WorkloadTable views, in order.
    """
    g = len(chunk_stats)
    if g != mesh.devices.size:
        raise ValueError(f"group of {g} chunks on a {mesh.devices.size}"
                         "-device mesh; groups must fill the mesh")
    if len({len(cs) for cs in chunk_stats}) != 1 or \
            len({len(cd) for cd in chunk_designs}) != 1:
        raise ValueError("chunks in a sharded group must share scenario "
                         "and design counts")
    width = pad_width(max(len(s.streams)
                          for cs in chunk_stats for s in cs))
    batches = [pack(tuple(cs), width=width) for cs in chunk_stats]
    stacked = [np.stack([getattr(b, f) for b in batches])
               for f in ("bytes_total", "is_write", "reuse_distance",
                         "dram_visible", "mask", "macs")]
    vecs = [np.stack(v) for v in
            zip(*(_design_vectors(tuple(cd)) for cd in chunk_designs))]
    pmat = np.stack([_platform_vector(p) for p in platforms])
    with enable_x64():
        out = _sharded_fold(mesh)(*stacked, *vecs, pmat)
    out = {k: np.asarray(v) for k, v in out.items()}
    return [_tables_from({k: v[i] for k, v in out.items()},
                         batches[i].keys, tuple(chunk_designs[i]),
                         tuple(platforms))
            for i in range(g)]


# ---------------------------------------------------------------------------
# Bucketed evaluation + warmup (the concurrent sweep service's fold path)
# ---------------------------------------------------------------------------


def _pad_axis(a: np.ndarray, n: int, fill) -> np.ndarray:
    """Pad the leading axis of ``a`` to length ``n`` with ``fill``."""
    if a.shape[0] == n:
        return a
    out = np.full((n,) + a.shape[1:], fill, dtype=a.dtype)
    out[:a.shape[0]] = a
    return out


def evaluate_bucketed(stats_seq: Sequence[TrafficStats],
                      designs: Sequence[CacheDesign],
                      platforms: Sequence[Platform] = (GTX_1080TI,),
                      ) -> tuple[WorkloadTable, ...]:
    """Shape-bucketed, uncached fold — the sweep service's evaluation path.

    Pads the scenario axis, the design axis, and the stream width each to
    its power-of-two bucket and slices the real cells back out of the
    kernel output.  Padding is inert by construction: scenario rows carry
    zero bytes, infinite reuse distance, zero MACs, and a False mask;
    design columns are all-zero vectors (zero capacity means every stream
    misses, but the column is dropped before anything reads it).  The set
    of compiled kernel shapes is therefore O(log^3) over arbitrary
    request sizes — exactly the shapes :func:`warmup` pre-traces, which
    is what makes a warmed service answer never-seen specs at warm cost.

    Values match ``evaluate_platforms`` at <= 1e-12 relative (padding
    reassociates the stream reductions, so bit-identity is not claimed).
    Deliberately uncached like ``evaluate_chunk``: the service layers its
    own bounded result cache on top.
    """
    stats_seq = tuple(stats_seq)
    designs = tuple(designs)
    platforms = tuple(platforms)
    s, d = len(stats_seq), len(designs)
    sp = axis_bucket(s, S_BUCKET_FLOOR)
    dp = axis_bucket(d, D_BUCKET_FLOOR)
    width = pad_width(max(len(x.streams) for x in stats_seq))
    batch = pack(stats_seq, width=width)
    bt = _pad_axis(batch.bytes_total, sp, 0.0)
    iw = _pad_axis(batch.is_write, sp, False)
    rd = _pad_axis(batch.reuse_distance, sp, np.inf)
    vis = _pad_axis(batch.dram_visible, sp, False)
    mask = _pad_axis(batch.mask, sp, False)
    macs = _pad_axis(batch.macs, sp, 0.0)
    vecs = [np.pad(v, (0, dp - d)) for v in _design_vectors(designs)]
    pmat = np.stack([_platform_vector(p) for p in platforms])
    with enable_x64():
        out = _fold_kernel(bt, iw, rd, vis, mask, macs, *vecs, pmat)
    sliced = {}
    for k, v in out.items():
        v = np.asarray(v)
        if v.ndim == 1:                 # [s] platform-independent
            sliced[k] = v[:s]
        elif v.ndim == 2:               # [s, d] platform-independent
            sliced[k] = v[:s, :d]
        else:                           # [p, s, d]
            sliced[k] = v[:, :s, :d]
    return _tables_from(sliced, batch.keys, designs, platforms)


def fold_shape(n_scenarios: int, max_streams: int, n_designs: int,
               n_platforms: int) -> tuple[int, int, int, int]:
    """The (s, k, d, p) kernel shape ``evaluate_bucketed`` compiles for
    these axis sizes — the unit of warmup."""
    return (axis_bucket(n_scenarios, S_BUCKET_FLOOR), pad_width(max_streams),
            axis_bucket(n_designs, D_BUCKET_FLOOR), int(n_platforms))


def warmup_fold(shape: tuple[int, int, int, int]) -> None:
    """Compile (and prime the jit dispatch cache for) the fold kernel at
    one bucketed (s, k, d, p) shape by folding inert dummy data — the
    same argument shapes/dtypes ``evaluate_bucketed`` dispatches, so a
    later real request at this shape pays only numeric work (~ms), not
    the XLA compile (~0.5 s)."""
    s, k, d, p = shape
    zeros_sk = np.zeros((s, k))
    false_sk = np.zeros((s, k), dtype=bool)
    vec = np.zeros(d)
    pmat = np.ones((p, len(PLATFORM_FIELDS)))  # ones: no 0-divides
    with enable_x64():
        _fold_kernel(zeros_sk, false_sk, np.full((s, k), np.inf), false_sk,
                     false_sk, np.zeros(s), vec, vec, vec, vec, vec,
                     np.ones(d), pmat)


def warmup(scenario_buckets: Sequence[int] = (S_BUCKET_FLOOR, 16),
           width_buckets: Sequence[int] = (16, 1024),
           design_buckets: Sequence[int] = (D_BUCKET_FLOOR, 16),
           platform_counts: Sequence[int] = (1, 2)) -> int:
    """Pre-trace the fold kernel over a grid of common bucketed shapes
    (spec-independent warmup; the service's spec-driven warmup compiles
    exact request shapes instead).  Returns the number of distinct shapes
    compiled.  The defaults cover small CNN/LM specs (width 16) and the
    wide-scenario regime (googlenet train packs at width 1024)."""
    shapes = {fold_shape(s, k, d, p)
              for s in scenario_buckets for k in width_buckets
              for d in design_buckets for p in platform_counts}
    for shape in sorted(shapes):
        warmup_fold(shape)
    return len(shapes)


def dram_tx(stats_seq: Sequence[TrafficStats],
            capacities_bytes: Sequence[float]) -> np.ndarray:
    """[s, c] DRAM transactions at each capacity — the batched form of
    ``TrafficStats.dram_tx`` (paper Fig. 6's capacity sweep)."""
    batch = pack(stats_seq)
    caps = np.array([float(c) for c in capacities_bytes], dtype=np.float64)
    with enable_x64():
        out = _miss_tx_kernel(batch.bytes_total, batch.reuse_distance,
                              batch.dram_visible & batch.mask, caps)
    return np.asarray(out)


# cache_clear()/cache_info()-style hooks on the public entry points, so
# consumers (and the cache-key-drift test in tests/test_sweep.py) can
# observe and reset the memoization without reaching for the private
# lru-cached implementation.
evaluate.cache_clear = _evaluate_cached.cache_clear
evaluate.cache_info = _evaluate_cached.cache_info
evaluate_platforms.cache_clear = _evaluate_cached.cache_clear
evaluate_platforms.cache_info = _evaluate_cached.cache_info


def clear_caches() -> None:
    """Drop memoized stats and tables (benchmark reruns)."""
    stats_for.cache_clear()
    _evaluate_cached.cache_clear()

"""Cross-node DTCO analysis — the paper's framework claim taken across
technology nodes.

DeepNVM++'s pitch is that one cross-layer stack characterizes any NVM
technology at any node; Mishty & Sadi (2023) run exactly such a
design-technology co-optimization (DTCO) study for SOT-MRAM, one node at a
time, by hand.  With the technology node a first-class batched axis the
whole cross-node study is one declarative sweep: every (node x memory)
EDAP-tuned design at a fixed (iso-capacity) last-level cache size, folded
through the paper workloads in a single circuit-engine call plus a single
workload-engine call.

Each node is its own normalization group — a 7 nm STT cache is compared
against the 7 nm SRAM baseline, never the 16 nm one — which is the
per-node comparison the DTCO papers make.  The headline trend is the
paper's Fig. 9 argument projected across nodes: the 6T SRAM cell's leakage
worsens as the node shrinks (tech.SCALING_EXPONENTS) while the MRAM
flavors' storage cells do not leak, so the leakage (and with it EDP) gap
widens monotonically from 16 nm down to 7 nm.

Node parameters at non-anchor nodes are first-order Dennard-style
projections from the calibrated 16 nm anchor: every layer re-derives from
the node — the MTJ device (``mtj.device``), the bitcell fin sweep
(``bitcell.characterize``), the periphery timing/energy building blocks
(``cachemodel.periphery``), and the calibration coefficients
(``calibration.get``) — each through one documented exponent
(tech.*_SCALING_EXPONENTS), so the cross-node rows carry genuine
device-and-periphery signal, not anchor constants in disguise.

Two cross-node studies live here: the iso-capacity study (``analyze``,
every node at the same 3 MB) and the iso-AREA study (``isoarea_analyze``)
— at each node the SRAM area budget is re-derived and spent on the MRAM
capacity that fits it (``isoarea.corners(node=...)``), the deliverable the
node-aware projection layer unlocks.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

from repro.core import isoarea, sweep
from repro.core.isocap import CAPACITY_MB, INFER_BATCH, TRAIN_BATCH, MEMS
from repro.core.tech import (GTX_1080TI, Platform, TechNode,
                             TECH_16NM, TECH_12NM, TECH_10NM, TECH_7NM)
from repro.core.workloads import Workload, paper_workloads

# The DTCO node axis: the calibrated anchor plus the scaled projections.
NODES = (TECH_16NM, TECH_12NM, TECH_10NM, TECH_7NM)


@dataclasses.dataclass(frozen=True)
class DTCORow:
    """One (node, memory) column of the cross-node iso-capacity study."""

    node: str
    feature_nm: float
    mem: str
    capacity_mb: float
    leakage_w: float     # tuned-design leakage power (circuit layer)
    area_mm2: float
    # Workload-mean metrics normalized to the same-node SRAM baseline.
    energy_x: float
    leak_x: float
    edp_x: float
    runtime_x: float


def spec(workloads: dict[str, Workload] | None = None,
         capacity_mb: float = CAPACITY_MB,
         nodes: Sequence[TechNode] = NODES,
         platform: Platform = GTX_1080TI,
         infer_batch: int = INFER_BATCH,
         train_batch: int = TRAIN_BATCH) -> sweep.SweepSpec:
    """The cross-node study as one declarative sweep: (workload x stage)
    scenarios x (node x memory) iso-capacity designs."""
    workloads = workloads if workloads is not None else paper_workloads()
    return sweep.SweepSpec(
        name="dtco",
        scenarios=sweep.workload_scenarios(
            workloads, ((False, infer_batch), (True, train_batch))),
        designs=sweep.design_grid(MEMS, (capacity_mb,), nodes=nodes),
        platforms=(platform,))


def analyze(workloads: dict[str, Workload] | None = None,
            capacity_mb: float = CAPACITY_MB,
            nodes: Sequence[TechNode] = NODES,
            platform: Platform = GTX_1080TI,
            infer_batch: int = INFER_BATCH,
            train_batch: int = TRAIN_BATCH) -> list[DTCORow]:
    """One DTCORow per (node, memory): circuit-layer leakage/area of the
    tuned design plus scenario-mean normalized workload metrics."""
    s = spec(workloads, capacity_mb, nodes, platform,
             infer_batch, train_batch)
    return _rows(s)


def _rows(s: sweep.SweepSpec) -> list[DTCORow]:
    """Run a cross-node spec and fold it to one DTCORow per design point:
    circuit-layer leakage/area of the tuned design plus scenario-mean
    normalized workload metrics (each node against its own baseline)."""
    res = sweep.run(s)
    norm = res.norm_to()
    m = {name: norm.metric(name, include_dram=(name == "edp"))
         for name in ("energy", "leak", "edp", "runtime")}
    rows = []
    for j, p in enumerate(s.designs):
        d = res.designs[j]
        rows.append(DTCORow(
            node=p.node.name,
            feature_nm=p.node.feature_size_m * 1e9,
            mem=p.mem,
            capacity_mb=p.capacity_mb,
            leakage_w=d.leakage_w,
            area_mm2=d.area_mm2,
            energy_x=float(m["energy"][0, :, j].mean()),
            leak_x=float(m["leak"][0, :, j].mean()),
            edp_x=float(m["edp"][0, :, j].mean()),
            runtime_x=float(m["runtime"][0, :, j].mean()),
        ))
    return rows


# ---------------------------------------------------------------------------
# Cross-node iso-AREA study
# ---------------------------------------------------------------------------


def isoarea_spec(workloads: dict[str, Workload] | None = None,
                 sram_capacity_mb: float = CAPACITY_MB,
                 nodes: Sequence[TechNode] = NODES,
                 platform: Platform = GTX_1080TI,
                 infer_batch: int = INFER_BATCH,
                 train_batch: int = TRAIN_BATCH) -> sweep.SweepSpec:
    """The cross-node iso-AREA study as one declarative sweep.

    At every node the SRAM area budget is re-derived from that node's
    EDAP-tuned designs and spent on the largest-fitting MRAM capacities
    (``isoarea.corners(node=...)``) — so both the capacities *and* the
    normalization baseline are per node.  Each node's three corners share
    the ``(node.name, 0)`` normalization group, matching the node-suffixed
    ``DesignCorners`` symbolic form."""
    workloads = workloads if workloads is not None else paper_workloads()
    nodes = tuple(nodes)
    points = tuple(
        dataclasses.replace(
            p, group=(nd.name, 0) if len(nodes) > 1 else 0)
        for nd in nodes
        for p in isoarea.corners(sram_capacity_mb, node=nd))
    return sweep.SweepSpec(
        name="dtco_isoarea",
        scenarios=sweep.workload_scenarios(
            workloads, ((False, infer_batch), (True, train_batch))),
        designs=points,
        platforms=(platform,))


def isoarea_analyze(workloads: dict[str, Workload] | None = None,
                    sram_capacity_mb: float = CAPACITY_MB,
                    nodes: Sequence[TechNode] = NODES,
                    platform: Platform = GTX_1080TI,
                    infer_batch: int = INFER_BATCH,
                    train_batch: int = TRAIN_BATCH) -> list[DTCORow]:
    """One DTCORow per (node, memory) at that node's iso-area corners:
    the ``capacity_mb`` column carries the per-node iso-area capacity."""
    return _rows(isoarea_spec(workloads, sram_capacity_mb, nodes, platform,
                              infer_batch, train_batch))


def isoarea_headline(rows: Sequence[DTCORow],
                     ) -> dict[str, dict[str, float]]:
    """Cross-node iso-area trend claims: each MRAM flavor's iso-area
    capacity at both ends of the node sweep (the density advantage the
    area budget buys) and its leakage/EDP reduction there (the widening
    gap against same-node SRAM)."""
    by = {(r.node, r.mem): r for r in rows}
    node_order = list(dict.fromkeys(r.node for r in rows))
    first, last = node_order[0], node_order[-1]
    out: dict[str, dict[str, float]] = {
        "sram": dict(
            leak_w_first=by[first, "sram"].leakage_w,
            leak_w_last=by[last, "sram"].leakage_w,
            leak_growth=by[last, "sram"].leakage_w
            / by[first, "sram"].leakage_w,
        )}
    for mem in ("stt", "sot"):
        out[mem] = dict(
            capacity_mb_first=by[first, mem].capacity_mb,
            capacity_mb_last=by[last, mem].capacity_mb,
            leak_reduction_first=1.0 / by[first, mem].leak_x,
            leak_reduction_last=1.0 / by[last, mem].leak_x,
            edp_reduction_first=1.0 / by[first, mem].edp_x,
            edp_reduction_last=1.0 / by[last, mem].edp_x,
        )
    return out


def headline(rows: Sequence[DTCORow]) -> dict[str, dict[str, float]]:
    """Cross-node trend claims: SRAM leakage growth from the first to the
    last node of the sweep, and each MRAM flavor's leakage/EDP reduction at
    both ends (the widening-gap argument)."""
    by = {(r.node, r.mem): r for r in rows}
    node_order = list(dict.fromkeys(r.node for r in rows))
    first, last = node_order[0], node_order[-1]
    out: dict[str, dict[str, float]] = {
        "sram": dict(
            leak_w_first=by[first, "sram"].leakage_w,
            leak_w_last=by[last, "sram"].leakage_w,
            leak_growth=by[last, "sram"].leakage_w
            / by[first, "sram"].leakage_w,
        )}
    for mem in ("stt", "sot"):
        out[mem] = dict(
            leak_reduction_first=1.0 / by[first, mem].leak_x,
            leak_reduction_last=1.0 / by[last, mem].leak_x,
            edp_reduction_first=1.0 / by[first, mem].edp_x,
            edp_reduction_last=1.0 / by[last, mem].edp_x,
        )
    return out

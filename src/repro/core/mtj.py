"""Magnetic tunnel junction (MTJ) switching models — the circuit layer.

The paper (§III-A) characterizes perpendicular STT [Kim et al., CICC'15] and
SOT [Kazemi et al., TED'16] devices in SPICE against a commercial 16 nm PDK.
We cannot run a commercial PDK, so we implement the standard compact-model
physics those SPICE models encode and calibrate the device constants against
the paper's published Table I (see DESIGN.md §2, "Calibration methodology").

Switching dynamics: for write currents above the critical current Ic0 the
device is in the precessional regime, where the switching time follows

    t_sw(I) = A / (I / Ic0 - 1)            (Sun model, I > Ic0)

with A a device time constant.  Below ~1.2x Ic0 the thermally-assisted
regime takes over and the latency explodes; the characterization sweep never
selects that region.  Write energy is Joule dissipation in the write path:

    E_wr(I) = I^2 * R_path * t_sw(I)

For STT the write path is the MTJ itself (R_P / R_AP for the two switching
polarities); for SOT it is the heavy-metal line plus driver (read and write
paths are decoupled, which is the whole point of SOT).

Technology nodes: the Table I anchors are 16 nm devices.  ``device(flavor,
node)`` projects them to other nodes through the documented exponents in
``tech.MTJ_SCALING_EXPONENTS`` (ground rules per the SOT-MRAM DTCO study,
arXiv 2303.12310): STT's Ic0 is retention-pinned and barely falls while the
access drive derates — the STT scaling wall — whereas SOT's Ic0 tracks the
shrinking heavy-metal track and scales gracefully.
"""

from __future__ import annotations

import dataclasses
import functools

from repro.core import tech
from repro.core.tech import TechNode, TECH_16NM


@dataclasses.dataclass(frozen=True)
class MTJDevice:
    """Compact-model constants for one magnetic technology flavor."""

    name: str
    ic0_set_a: float          # critical current, set transition (P -> AP)
    ic0_reset_a: float        # critical current, reset transition (AP -> P)
    tau_set_s: float          # precessional time constant A, set
    tau_reset_s: float        # precessional time constant A, reset
    r_set_ohm: float          # effective write-path resistance, set
    r_reset_ohm: float        # effective write-path resistance, reset
    r_read_ohm: float         # read-path resistance (through MTJ)
    read_disturb_frac: float  # max I_read / Ic0 before disturb errors
    # Sensing: the bitline split must reach the sense threshold; at device
    # level the paper reports 650 ps for both flavors (same MTJ stack).
    sense_time_s: float = 650e-12


# --- Calibrated devices -----------------------------------------------------
# Anchors: paper Table I.  Derivations (V_dd = 0.8 V, I_on = 42 uA/fin):
#   STT, 4 fins -> I_wr = 168 uA.
#     set:   8.40 ns = A_set  / (168/140 - 1)        => A_set   = 1.68 ns
#     reset: 7.78 ns = A_rst  / (168/138 - 1)        => A_rst   = 1.69 ns
#     E_set   = I^2 R t = (168u)^2 R 8.40n = 1.1 pJ  => R_P     = 4.64 kOhm
#     E_reset = (168u)^2 R 7.78n          = 2.2 pJ   => R_AP    = 10.0 kOhm
#     (TMR = (R_AP - R_P)/R_P ~ 116%, a normal perpendicular-MTJ value.)
#   SOT, 3 write fins -> I_wr = 126 uA, through the heavy-metal line.
#     set:   313 ps = A_set / (126/100 - 1)          => A_set   = 81.4 ps
#     reset: 243 ps = A_rst / (126/100 - 1)          => A_rst   = 63.2 ps
#     E = 0.08 pJ = (126u)^2 R 313p                  => R_eff   = 16.1 kOhm
#     (effective write-path impedance including the write driver).
STT_16NM = MTJDevice(
    name="stt",
    ic0_set_a=140e-6,
    ic0_reset_a=138e-6,
    tau_set_s=1.68e-9,
    tau_reset_s=1.69e-9,
    r_set_ohm=4.64e3,
    r_reset_ohm=10.0e3,
    r_read_ohm=4.64e3,
    read_disturb_frac=0.60,
)

SOT_16NM = MTJDevice(
    name="sot",
    ic0_set_a=100e-6,
    ic0_reset_a=100e-6,
    tau_set_s=81.4e-12,
    tau_reset_s=63.2e-12,
    r_set_ohm=16.1e3,
    r_reset_ohm=20.7e3,   # E_reset = 0.08 pJ at 243 ps (Table I anchor)
    r_read_ohm=4.64e3,     # read still goes through the MTJ stack
    read_disturb_frac=1.0,  # decoupled read path: no write-current disturb
)

_ANCHORS = {"stt": STT_16NM, "sot": SOT_16NM}


@functools.cache
def device(flavor: str, node: TechNode = TECH_16NM) -> MTJDevice:
    """Node-projected MTJ device: the 16 nm Table I anchor scaled by the
    documented ``tech.MTJ_SCALING_EXPONENTS`` rules (Ic0, time constants,
    path resistances, sense window — each ``anchor * s**exp``).

    At the anchor s = 1.0 exactly, so every field is a bit-exact
    multiply-by-1.0 of the Table I calibration — the projection layer
    cannot drift the anchor.  ``read_disturb_frac`` is a device-topology
    property (shared vs decoupled read path), not a scaled quantity.
    """
    anchor = _ANCHORS[flavor]
    s = tech.scale_factor(node)
    exps = tech.MTJ_SCALING_EXPONENTS[flavor]
    return dataclasses.replace(
        anchor, **{f: getattr(anchor, f) * s ** e for f, e in exps.items()})


def custom_device(flavor: str, node: TechNode = TECH_16NM,
                  **overrides: float) -> MTJDevice:
    """Node-projected device with explicit field overrides — the standard
    (non-relaxed) re-evaluation entry for inverse design: a converged
    continuous leaf (say ``ic0_set_a``) replaces the projected anchor while
    every untouched field keeps its ``device(flavor, node)`` value.
    Uncached on purpose: override values come from optimizer trajectories,
    not a small enumerable grid."""
    return dataclasses.replace(device(flavor, node), **overrides)


def switching_time(dev: MTJDevice, i_write_a: float, *, reset: bool) -> float:
    """Precessional switching time; +inf below the critical current."""
    ic0 = dev.ic0_reset_a if reset else dev.ic0_set_a
    tau = dev.tau_reset_s if reset else dev.tau_set_s
    overdrive = i_write_a / ic0 - 1.0
    if overdrive <= 0.0:
        return float("inf")
    return tau / overdrive


def switching_energy(dev: MTJDevice, i_write_a: float, *, reset: bool) -> float:
    """Joule write energy I^2 * R * t_sw for the given polarity."""
    t = switching_time(dev, i_write_a, reset=reset)
    r = dev.r_reset_ohm if reset else dev.r_set_ohm
    return i_write_a * i_write_a * r * t


def sense_energy(dev: MTJDevice, i_read_a: float, vdd_v: float,
                 sense_time_s: float | None = None) -> float:
    """Read (sense) energy: the read current is drawn from VDD for the
    sensing window.  The paper's Table I values correspond to
    I_read = 146 uA (STT: 4 fins, wordline under-driven to respect the
    read-disturb limit) and I_read = 42 uA (SOT: 1-fin dedicated path)."""
    t = dev.sense_time_s if sense_time_s is None else sense_time_s
    return vdd_v * i_read_a * t


def max_read_current(dev: MTJDevice) -> float:
    """Read-disturb ceiling: the largest safe read current.  For STT the
    read current flows through the same MTJ as writes, so it must stay well
    below Ic0; SOT's decoupled path removes the limit (returns +inf)."""
    if dev.read_disturb_frac >= 1.0:
        return float("inf")
    return dev.read_disturb_frac * min(dev.ic0_set_a, dev.ic0_reset_a)

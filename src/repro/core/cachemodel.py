"""NVSim-style cache PPA model — the microarchitecture layer.

Given a characterized bitcell (core/bitcell.py) and a cache capacity, this
model explores internal organizations (banks x subarray rows x cols, and the
NVSim access types) and produces read/write latency, read/write energy,
leakage power, and area — the quantities of paper Table II.

Structure (CACTI/NVSim lineage):

  cache = banks, H-tree-connected; bank = grid of subarrays (mats);
  subarray = rows x cols bitcell array + row decoder + wordline driver +
  bitline pairs + sense amplifiers + write drivers.

  read latency  = decoder + wordline RC + bitline development + sense +
                  way select + H-tree (in + out)
  write latency = decoder + wordline RC + cell write time + H-tree
  read energy   = sensed-bit energy + bitline charging + decoder + H-tree
  write energy  = flipped-bit write energy + bitline charging + periphery
  leakage       = storage-cell leakage (SRAM only, ~0 for MRAM) + periphery
                  leakage (decoders, sense amps, H-tree repeaters)
  area          = bitcell array area / layout efficiency + periphery area

Access types (NVSim semantics):
  normal     — tag and data in parallel, all ways sensed, way-select at the
               output mux (balanced).
  fast       — everything in parallel including data-out of all ways
               (lowest latency, highest energy).
  sequential — tag first, then only the matching data way (lowest read
               energy, highest latency).

Like NVSim against a PDK, the model's absolute scale is calibrated: per-
technology multipliers (core/calibration.py) anchor the EDAP-tuned 3 MB
(iso-capacity) and 7/10 MB (iso-area) designs to paper Table II, and the
structural model provides the scaling behaviour across 1–64 MB (Fig. 9).
The periphery building blocks (gate delay, sense amp, wire capacitances,
H-tree terms) are node-derived: :class:`Periphery` projects the 16 nm
anchor constants through ``tech.PERIPHERY_SCALING_EXPONENTS``, so a scaled
node re-times and re-energizes the periphery, not just the array.
Bit-flip statistics: MRAM writes use differential write (only flipped bits
switch; Flip-N-Write-style, standard for MRAM macros) with the measured DL
bit-flip probability FLIP_P.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import math

import numpy as np

from repro.core import tech
from repro.core.bitcell import Bitcell, characterize
from repro.core.tech import TechNode, TECH_16NM, mm2_from_um2

LINE_BYTES = 128          # transaction granularity (paper: 128 B lines)
ASSOC = 16                # 1080 Ti L2 associativity (Table IV)
TAG_BITS = 28             # tag + state bits per line
FLIP_P = 0.18             # measured DL-tensor bit-flip probability per write

ACCESS_TYPES = ("normal", "fast", "sequential")

# Subarray aspect design space (NVSim's internal sweep).  Public: the
# batched engine (core/engine.py) builds its structure-of-arrays org grid
# from the same choices, in the same itertools.product order.
ROW_CHOICES = (128, 256, 512, 1024)
COL_CHOICES = (256, 512, 1024, 2048)
BANK_CHOICES = (1, 2, 4, 8, 16, 32)

# Periphery timing/energy building blocks at 16 nm (pre-calibration scale).
# These are the *anchor* values; every node — including the anchor itself —
# consumes them through the ``Periphery`` projection below, so the batched
# engine and the scalar model read identical node-derived quantities.
_T_GATE = 18e-12          # FO4-ish gate delay
_T_SENSE_AMP = 110e-12    # sense-amp resolve time
_E_GATE = 0.9e-15         # per-gate switching energy
_HTREE_NS_PER_MM = 0.33   # repeated-wire delay
_HTREE_PJ_PER_MM_BIT = 0.021
_C_BITLINE_PER_ROW = 0.20e-15   # F per cell on the bitline
_C_WORDLINE_PER_COL = 0.22e-15  # F per cell on the wordline


@dataclasses.dataclass(frozen=True)
class Periphery:
    """Node-derived periphery timing/energy building blocks.

    One frozen bundle of every periphery constant the PPA equations read,
    projected from the 16 nm anchor by ``tech.PERIPHERY_SCALING_EXPONENTS``
    (each field ``anchor * s**exp``; exactly the anchor values at s = 1).
    Both the scalar :class:`CacheModel` and the batched engine
    (``engine.NODE_FIELDS``) consume these per-node values — there are no
    anchor-pinned periphery constants left in the equations.
    """

    t_gate_s: float                 # FO4-ish gate delay [s]
    t_sense_amp_s: float            # sense-amp resolve time [s]
    e_gate_j: float                 # per-gate switching energy [J]
    htree_ns_per_mm: float        # repeated-wire delay [ns/mm]
    htree_pj_per_mm_bit: float    # H-tree wire energy [pJ/(mm*bit)]
    c_bitline_per_row_f: float      # F per cell on the bitline
    c_wordline_per_col_f: float     # F per cell on the wordline

    def as_array(self) -> np.ndarray:
        """Parameter vector (float64, PERIPHERY_FIELDS order): the
        periphery suffix of one ``engine.node_row``."""
        return np.array([getattr(self, f) for f in PERIPHERY_FIELDS],
                        dtype=np.float64)


# Field order is the engine's packing order (engine.NODE_FIELDS suffix).
PERIPHERY_FIELDS = tuple(f.name for f in dataclasses.fields(Periphery))

_PERIPHERY_16NM = Periphery(
    t_gate_s=_T_GATE,
    t_sense_amp_s=_T_SENSE_AMP,
    e_gate_j=_E_GATE,
    htree_ns_per_mm=_HTREE_NS_PER_MM,
    htree_pj_per_mm_bit=_HTREE_PJ_PER_MM_BIT,
    c_bitline_per_row_f=_C_BITLINE_PER_ROW,
    c_wordline_per_col_f=_C_WORDLINE_PER_COL,
)


@functools.cache
def periphery(node: TechNode = TECH_16NM) -> Periphery:
    """The periphery building blocks at ``node``: the 16 nm anchor scaled
    field-by-field through ``tech.PERIPHERY_SCALING_EXPONENTS``."""
    s = tech.scale_factor(node)
    return Periphery(**{
        f: getattr(_PERIPHERY_16NM, f)
        * s ** tech.PERIPHERY_SCALING_EXPONENTS[f]
        for f in PERIPHERY_FIELDS})


# SRAM-only capacity-stress exponents.  Holding SRAM frequency and yield at
# LLC-scale capacities requires HP (leakier) cells, redundancy, and deeper
# banking; NVSim's SRAM designs show super-linear leakage and latency growth
# that our first-order structural terms do not capture.  The exponents are
# calibrated against the paper's §IV-C scalability claims (up to 31x/36x
# energy, 2.1x/2.6x latency, 65x/95x EDP at 32 MB) and are exactly 1.0 at
# the 3 MB Table II anchor.  MRAM arrays stay compact (0.29-0.34x cell
# area), so no stress factor applies.
_SRAM_LAT_STRESS_EXP = 0.28
_SRAM_LEAK_STRESS_EXP = 0.22
_STRESS_ANCHOR_MB = 3.0


@dataclasses.dataclass(frozen=True)
class CacheOrg:
    banks: int
    rows: int
    cols: int
    access: str

    def __str__(self) -> str:
        return f"{self.banks}b x {self.rows}r x {self.cols}c / {self.access}"


@dataclasses.dataclass(frozen=True)
class CacheDesign:
    """One evaluated cache design point — a paper Table II column."""

    mem: str
    capacity_bytes: int
    org: CacheOrg
    read_latency_s: float
    write_latency_s: float
    read_energy_j: float
    write_energy_j: float
    leakage_w: float
    area_mm2: float

    @property
    def capacity_mb(self) -> float:
        return self.capacity_bytes / 2**20

    def edp_per_access(self) -> float:
        e = 0.5 * (self.read_energy_j + self.write_energy_j)
        d = 0.5 * (self.read_latency_s + self.write_latency_s)
        return e * d

    def edap(self) -> float:
        """calculate(EDAP) of paper Algorithm 1."""
        return self.edp_per_access() * self.area_mm2


def _data_bits(capacity_bytes: int) -> int:
    return capacity_bytes * 8


def _tag_bits(capacity_bytes: int) -> int:
    return (capacity_bytes // LINE_BYTES) * TAG_BITS


class CacheModel:
    """Evaluates cache design points for one memory technology."""

    def __init__(self, mem: str, node: TechNode = TECH_16NM,
                 cell: Bitcell | None = None, calibration=None):
        from repro.core import calibration as _cal  # local: avoids cycle
        self.mem = mem
        self.node = node
        self.peri = periphery(node)
        self.cell = cell if cell is not None else characterize(mem, node)
        self.cal = calibration if calibration is not None \
            else _cal.get(mem, node)

    # -- geometry ------------------------------------------------------------

    def _subarrays(self, capacity_bytes: int, org: CacheOrg) -> int:
        bits = _data_bits(capacity_bytes) + _tag_bits(capacity_bytes)
        per_subarray = org.rows * org.cols
        return max(1, math.ceil(bits / per_subarray))

    def _array_area_mm2(self, capacity_bytes: int) -> float:
        bits = _data_bits(capacity_bytes) + _tag_bits(capacity_bytes)
        cell_um2 = self.cell.area_norm * self.node.sram_cell_area_um2
        return mm2_from_um2(bits * cell_um2) / 0.85  # layout efficiency

    def _periphery_area_mm2(self, capacity_bytes: int) -> float:
        # Decoders/sense-amps/H-tree: linear + sqrt(capacity) terms; the
        # coefficients are per-technology (bigger drive -> bigger drivers)
        # and carry the Table II calibration.
        cap_mb = capacity_bytes / 2**20
        return self.cal.peri_area_lin * cap_mb + self.cal.peri_area_sqrt * math.sqrt(cap_mb)

    def area_mm2(self, capacity_bytes: int) -> float:
        return self._array_area_mm2(capacity_bytes) + self._periphery_area_mm2(capacity_bytes)

    def _htree_mm(self, capacity_bytes: int, org: CacheOrg) -> float:
        # Half-perimeter of the die area occupied by the cache, as the
        # average H-tree route; deeper banking shortens per-bank segments
        # but adds hops — net modeled as sqrt(area)*(1 + log2(banks)/8).
        side = math.sqrt(self.area_mm2(capacity_bytes))
        return side * (1.0 + math.log2(org.banks) / 8.0)

    def _stress(self, capacity_bytes: int, exp: float) -> float:
        if self.mem != "sram":
            return 1.0
        return (capacity_bytes / 2**20 / _STRESS_ANCHOR_MB) ** exp

    # -- latency -------------------------------------------------------------

    def _decoder_delay(self, org: CacheOrg) -> float:
        return math.log2(org.rows) * self.peri.t_gate_s

    def _wordline_delay(self, org: CacheOrg) -> float:
        c_wl = org.cols * self.peri.c_wordline_per_col_f
        return 2.2 * c_wl * (self.node.vdd_v / self.node.ion_per_fin_a) * 0.05

    def _bitline_time(self, org: CacheOrg) -> float:
        """Bitline development to the sense threshold.

        MRAM: current-mode sensing — the read current must slew the bitline
        capacitance by the sense margin, then the device sense time applies.
        SRAM: differential discharge by the (larger) cell read current.
        """
        c_bl = org.rows * self.peri.c_bitline_per_row_f
        i_read = self.cell.read_current_a
        t_slew = c_bl * self.node.sense_voltage_v / i_read
        return t_slew + self.cell.sense_latency_s + self.peri.t_sense_amp_s

    def _routing_delay(self, capacity_bytes: int, org: CacheOrg) -> float:
        """Predecoder + subarray-select tree: grows with subarray count —
        the term that penalizes over-fragmented organizations and gives
        Algorithm 1 an interior optimum."""
        n_sub = self._subarrays(capacity_bytes, org)
        return 2.0 * self.peri.t_gate_s * math.log2(max(2, n_sub))

    def read_latency(self, capacity_bytes: int, org: CacheOrg) -> float:
        ht = self._htree_mm(capacity_bytes, org) \
            * self.peri.htree_ns_per_mm * 1e-9
        route = self._routing_delay(capacity_bytes, org)
        array = self._decoder_delay(org) + self._wordline_delay(org) + self._bitline_time(org)
        tag = self._decoder_delay(org) + self._wordline_delay(org) + 0.4 * self._bitline_time(org)
        if org.access == "sequential":
            lat = ht + route + tag + array + 2 * self.peri.t_gate_s
        elif org.access == "fast":
            lat = ht + route + array + self.peri.t_gate_s
        else:  # normal: tag || data, way-select mux at the end
            lat = ht + route + max(tag, array) + 3 * self.peri.t_gate_s
        return lat * self.cal.k_read_lat \
            * self._stress(capacity_bytes, _SRAM_LAT_STRESS_EXP)

    def write_latency(self, capacity_bytes: int, org: CacheOrg) -> float:
        ht = self._htree_mm(capacity_bytes, org) \
            * self.peri.htree_ns_per_mm * 1e-9
        lat = (ht + self._routing_delay(capacity_bytes, org)
               + self._decoder_delay(org) + self._wordline_delay(org)
               + self.cell.write_latency_avg_s)
        return lat * self.cal.k_write_lat \
            * self._stress(capacity_bytes, _SRAM_LAT_STRESS_EXP)

    # -- energy ---------------------------------------------------------------

    def read_energy(self, capacity_bytes: int, org: CacheOrg) -> float:
        bits = LINE_BYTES * 8
        ways_sensed = {"normal": ASSOC, "fast": ASSOC, "sequential": 1}[org.access]
        sense = bits * ways_sensed * self.cell.sense_energy_j
        # bitline charging: read current drawn for the bitline time across
        # the sensed columns
        c_bl = org.rows * self.peri.c_bitline_per_row_f
        bitline = bits * ways_sensed * c_bl * self.node.vdd_v * self.node.vdd_v
        ht = (self._htree_mm(capacity_bytes, org)
              * self.peri.htree_pj_per_mm_bit * 1e-12 * bits)
        decoder = math.log2(org.rows) * 64 * self.peri.e_gate_j
        route = self._subarrays(capacity_bytes, org) * 4 * self.peri.e_gate_j
        return (sense + bitline + ht + decoder + route) * self.cal.k_read_e

    def write_energy(self, capacity_bytes: int, org: CacheOrg) -> float:
        bits = LINE_BYTES * 8
        flips = bits * (FLIP_P if self.mem != "sram" else 1.0)
        cellw = flips * self.cell.write_energy_avg_j
        c_bl = org.rows * self.peri.c_bitline_per_row_f
        bitline = bits * c_bl * self.node.vdd_v * self.node.vdd_v * 2.0
        ht = (self._htree_mm(capacity_bytes, org)
              * self.peri.htree_pj_per_mm_bit * 1e-12 * bits)
        decoder = math.log2(org.rows) * 64 * self.peri.e_gate_j
        route = self._subarrays(capacity_bytes, org) * 4 * self.peri.e_gate_j
        return (cellw + bitline + ht + decoder + route) * self.cal.k_write_e

    # -- leakage ---------------------------------------------------------------

    def leakage_w(self, capacity_bytes: int, org: CacheOrg) -> float:
        del org  # periphery leakage is carried by the calibrated fit
        bits = _data_bits(capacity_bytes) + _tag_bits(capacity_bytes)
        cells = bits * self.cell.cell_leakage_w \
            * self._stress(capacity_bytes, _SRAM_LEAK_STRESS_EXP)
        cap_mb = capacity_bytes / 2**20
        peri = self.cal.leak_lin * cap_mb + self.cal.leak_sqrt * math.sqrt(cap_mb)
        return cells + peri

    # -- full evaluation ---------------------------------------------------------

    def evaluate(self, capacity_bytes: int, org: CacheOrg) -> CacheDesign:
        """One design point — a single-element batch on the engine.

        The per-quantity scalar methods above remain the pure-Python
        reference implementation (exercised by the engine parity tests and
        by ``evaluate_scalar``); this entry point shares the batched code
        path with the full sweep.
        """
        return self.evaluate_batch(capacity_bytes, (org,))[0]

    def evaluate_batch(self, capacity_bytes: int,
                       orgs) -> list[CacheDesign]:
        """Evaluate many organizations in one batched engine call."""
        from repro.core import engine  # deferred: engine imports this module
        orgs = tuple(orgs)
        out = engine.evaluate((capacity_bytes,), orgs, mems=(self.mem,),
                              cells=(self.cell,), cals=(self.cal,),
                              nodes=self.node)
        return [CacheDesign(
            mem=self.mem,
            capacity_bytes=capacity_bytes,
            org=org,
            read_latency_s=float(out["read_latency_s"][0, 0, 0, i]),
            write_latency_s=float(out["write_latency_s"][0, 0, 0, i]),
            read_energy_j=float(out["read_energy_j"][0, 0, 0, i]),
            write_energy_j=float(out["write_energy_j"][0, 0, 0, i]),
            leakage_w=float(out["leakage_w"][0, 0, 0]),
            area_mm2=float(out["area_mm2"][0, 0, 0]),
        ) for i, org in enumerate(orgs)]

    def evaluate_scalar(self, capacity_bytes: int, org: CacheOrg) -> CacheDesign:
        """The original pure-Python evaluation (parity/benchmark reference)."""
        return CacheDesign(
            mem=self.mem,
            capacity_bytes=capacity_bytes,
            org=org,
            read_latency_s=self.read_latency(capacity_bytes, org),
            write_latency_s=self.write_latency(capacity_bytes, org),
            read_energy_j=self.read_energy(capacity_bytes, org),
            write_energy_j=self.write_energy(capacity_bytes, org),
            leakage_w=self.leakage_w(capacity_bytes, org),
            area_mm2=self.area_mm2(capacity_bytes),
        )

    def design_space(self, capacity_bytes: int):
        """All internal organizations NVSim would sweep for this capacity."""
        for banks, rows, cols, access in itertools.product(
                BANK_CHOICES, ROW_CHOICES, COL_CHOICES, ACCESS_TYPES):
            bits = _data_bits(capacity_bytes)
            if banks * rows * cols > 4 * bits:   # degenerate: mostly empty
                continue
            if bits / (banks * rows * cols) > 4096:  # too few subarrays
                continue
            yield CacheOrg(banks=banks, rows=rows, cols=cols, access=access)

"""Iso-capacity analysis — paper §III-C / §IV-A (Figs. 3, 4, 5).

Same cache capacity (3 MB) for SRAM, STT-MRAM, SOT-MRAM; workload memory
statistics from the traffic model; outputs normalized dynamic/leakage
energy breakdowns, total energy, and EDP per workload for inference
(batch 4) and training (batch 64), plus the batch-size sweep of Fig. 5.

Both analyses are thin adapters over the unified sweep pipeline
(core/sweep.py): they declare a SweepSpec (scenarios x designs x
platform) and materialize IsoCapRows from the one batched evaluation it
lowers to — no per-analysis designs/fold plumbing.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

from repro.core import sweep
from repro.core.sweep import MEMS  # noqa: F401  (re-export: analyses' axis)
from repro.core.tech import Platform, GTX_1080TI
from repro.core.traffic import EnergyReport
from repro.core.workloads import Workload, paper_workloads

INFER_BATCH = 4
TRAIN_BATCH = 64
CAPACITY_MB = 3


def designs_at(capacity_mb: float) -> dict[str, object]:
    """EDAP-tuned designs for all technologies at one capacity, read from
    the shared memoized batched sweep (one engine evaluation)."""
    _, designs = sweep.lower_designs(sweep.design_grid(MEMS, (capacity_mb,)))
    return dict(zip(MEMS, designs))


@dataclasses.dataclass(frozen=True)
class IsoCapRow:
    """One (workload, stage) row across all memories."""

    workload: str
    training: bool
    batch: int
    reports: dict[str, EnergyReport]
    read_write_ratio: float

    def norm(self, metric: str, mem: str, include_dram: bool = False) -> float:
        """Value for `mem` normalized to SRAM (paper figure convention)."""
        get = {
            "dyn": lambda r: r.dyn_j,
            "leak": lambda r: r.leak_j,
            "energy": lambda r: r.total_j(include_dram),
            "edp": lambda r: r.edp(include_dram),
            "runtime": lambda r: r.runtime_s,
        }[metric]
        return get(self.reports[mem]) / get(self.reports["sram"])


def rows_from_result(result: sweep.SweepResult,
                     platform_index: int = 0) -> list[IsoCapRow]:
    """Materialize one IsoCapRow per scenario from a sweep result (used by
    every memory-unique-design analysis: isocap, isoarea, Fig. 5)."""
    table = result.tables[platform_index]
    ratios = table.read_write_ratio
    return [IsoCapRow(workload, training, batch, table.reports(i),
                      float(ratios[i]))
            for i, (workload, batch, training) in enumerate(table.scenarios)]


def spec(workloads: dict[str, Workload] | None = None,
         capacity_mb: float = CAPACITY_MB,
         platform: Platform = GTX_1080TI,
         infer_batch: int = INFER_BATCH,
         train_batch: int = TRAIN_BATCH) -> sweep.SweepSpec:
    """The Figs. 3/4 study as one declarative sweep (the spec the golden
    ``specs/isocap.json`` document resolves to)."""
    workloads = workloads if workloads is not None else paper_workloads()
    return sweep.SweepSpec(
        name="isocap",
        scenarios=sweep.workload_scenarios(
            workloads, ((False, infer_batch), (True, train_batch))),
        designs=sweep.design_grid(MEMS, (capacity_mb,)),
        platforms=(platform,))


def analyze(workloads: dict[str, Workload] | None = None,
            capacity_mb: float = CAPACITY_MB,
            platform: Platform = GTX_1080TI,
            infer_batch: int = INFER_BATCH,
            train_batch: int = TRAIN_BATCH) -> list[IsoCapRow]:
    """Figs. 3/4: per workload x {inference, training} x memory — one
    declarative sweep over the iso-capacity design grid."""
    return rows_from_result(sweep.run(spec(
        workloads, capacity_mb, platform, infer_batch, train_batch)))


def batch_sweep(workload: Workload, training: bool,
                batches: Sequence[int] = (1, 2, 4, 8, 16, 32, 64),
                capacity_mb: float = CAPACITY_MB,
                platform: Platform = GTX_1080TI) -> list[IsoCapRow]:
    """Fig. 5: EDP vs batch size (paper: AlexNet, 3 MB iso-capacity) — the
    batch axis is the scenario dimension of the sweep."""
    spec = sweep.SweepSpec(
        name="isocap-batch",
        scenarios=sweep.workload_scenarios(
            (workload,), tuple((training, b) for b in batches)),
        designs=sweep.design_grid(MEMS, (capacity_mb,)),
        platforms=(platform,))
    return rows_from_result(sweep.run(spec))


def summary(rows: list[IsoCapRow]) -> dict[str, dict[str, float]]:
    """Aggregates matching the paper's §IV-A prose claims."""
    out: dict[str, dict[str, float]] = {}
    n = len(rows)
    for mem in ("stt", "sot"):
        out[mem] = dict(
            dyn_energy_x=sum(r.norm("dyn", mem) for r in rows) / n,
            leak_reduction=sum(1 / r.norm("leak", mem) for r in rows) / n,
            energy_reduction=sum(1 / r.norm("energy", mem) for r in rows) / n,
            edp_reduction_mean=sum(1 / r.norm("edp", mem, True) for r in rows) / n,
            edp_reduction_max=max(1 / r.norm("edp", mem, True) for r in rows),
        )
    sram_read_share = [
        r.reports["sram"].dyn_read_j / r.reports["sram"].dyn_j for r in rows]
    out["sram"] = dict(read_share_of_dyn=sum(sram_read_share) / n)
    return out

"""Iso-capacity analysis — paper §III-C / §IV-A (Figs. 3, 4, 5).

Same cache capacity (3 MB) for SRAM, STT-MRAM, SOT-MRAM; workload memory
statistics from the traffic model; outputs normalized dynamic/leakage
energy breakdowns, total energy, and EDP per workload for inference
(batch 4) and training (batch 64), plus the batch-size sweep of Fig. 5.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

from repro.core import engine, traffic
from repro.core.tech import Platform, GTX_1080TI
from repro.core.traffic import EnergyReport
from repro.core.workloads import Workload, paper_workloads

MEMS = ("sram", "stt", "sot")
INFER_BATCH = 4
TRAIN_BATCH = 64
CAPACITY_MB = 3


def designs_at(capacity_mb: float) -> dict[str, object]:
    """EDAP-tuned designs for all technologies at one capacity, read from
    the shared memoized batched sweep (one engine evaluation)."""
    cap_bytes = int(capacity_mb * 2**20)
    table = engine.design_table(tuple(MEMS), (cap_bytes,))
    return {m: table.tuned(m, cap_bytes) for m in MEMS}


@dataclasses.dataclass(frozen=True)
class IsoCapRow:
    """One (workload, stage) row across all memories."""

    workload: str
    training: bool
    batch: int
    reports: dict[str, EnergyReport]
    read_write_ratio: float

    def norm(self, metric: str, mem: str, include_dram: bool = False) -> float:
        """Value for `mem` normalized to SRAM (paper figure convention)."""
        get = {
            "dyn": lambda r: r.dyn_j,
            "leak": lambda r: r.leak_j,
            "energy": lambda r: r.total_j(include_dram),
            "edp": lambda r: r.edp(include_dram),
            "runtime": lambda r: r.runtime_s,
        }[metric]
        return get(self.reports[mem]) / get(self.reports["sram"])


def analyze(workloads: dict[str, Workload] | None = None,
            capacity_mb: float = CAPACITY_MB,
            platform: Platform = GTX_1080TI,
            infer_batch: int = INFER_BATCH,
            train_batch: int = TRAIN_BATCH) -> list[IsoCapRow]:
    """Figs. 3/4: per workload x {inference, training} x memory."""
    workloads = workloads if workloads is not None else paper_workloads()
    designs = designs_at(capacity_mb)
    rows = []
    for w in workloads.values():
        for training, batch in ((False, infer_batch), (True, train_batch)):
            stats = traffic.build(w, batch, training)
            reports = {m: traffic.energy(stats, d, platform)
                       for m, d in designs.items()}
            rows.append(IsoCapRow(w.name, training, batch, reports,
                                  stats.read_write_ratio))
    return rows


def batch_sweep(workload: Workload, training: bool,
                batches: Sequence[int] = (1, 2, 4, 8, 16, 32, 64),
                capacity_mb: float = CAPACITY_MB,
                platform: Platform = GTX_1080TI) -> list[IsoCapRow]:
    """Fig. 5: EDP vs batch size (paper: AlexNet, 3 MB iso-capacity)."""
    designs = designs_at(capacity_mb)
    rows = []
    for batch in batches:
        stats = traffic.build(workload, batch, training)
        reports = {m: traffic.energy(stats, d, platform)
                   for m, d in designs.items()}
        rows.append(IsoCapRow(workload.name, training, batch, reports,
                              stats.read_write_ratio))
    return rows


def summary(rows: list[IsoCapRow]) -> dict[str, dict[str, float]]:
    """Aggregates matching the paper's §IV-A prose claims."""
    out: dict[str, dict[str, float]] = {}
    n = len(rows)
    for mem in ("stt", "sot"):
        out[mem] = dict(
            dyn_energy_x=sum(r.norm("dyn", mem) for r in rows) / n,
            leak_reduction=sum(1 / r.norm("leak", mem) for r in rows) / n,
            energy_reduction=sum(1 / r.norm("energy", mem) for r in rows) / n,
            edp_reduction_mean=sum(1 / r.norm("edp", mem, True) for r in rows) / n,
            edp_reduction_max=max(1 / r.norm("edp", mem, True) for r in rows),
        )
    sram_read_share = [
        r.reports["sram"].dyn_read_j / r.reports["sram"].dyn_j for r in rows]
    out["sram"] = dict(read_share_of_dyn=sum(sram_read_share) / n)
    return out

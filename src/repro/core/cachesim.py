"""Trace-driven cache simulation — the GPGPU-Sim replacement (iso-area).

Two engines:

  * `SetAssocCache` — an exact set-associative LRU write-back simulator.
    Used by the property tests to validate the analytic model, and usable
    directly on small traces.
  * `stack_distance_profile` — single-pass LRU stack-distance histogram
    (Mattson).  One pass over a trace yields the miss count for EVERY
    capacity simultaneously, which is how the Fig. 6 capacity sweep is
    produced cheaply.

Traces are sequences of block ids (ints) at a configurable granularity;
`trace_from_streams` lowers the analytic AccessStream representation into a
concrete interleaved trace for cross-validation.
"""

from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from collections.abc import Iterable, Sequence


@dataclasses.dataclass
class CacheStats:
    accesses: int = 0
    misses: int = 0
    writebacks: int = 0

    @property
    def miss_rate(self) -> float:
        return self.misses / max(1, self.accesses)


class SetAssocCache:
    """Exact set-associative LRU write-back cache (one block granularity)."""

    def __init__(self, capacity_blocks: int, assoc: int = 16):
        if capacity_blocks < 1:
            raise ValueError(f"capacity_blocks must be >= 1, got {capacity_blocks}")
        if assoc < 1:
            raise ValueError(f"assoc must be >= 1, got {assoc}")
        # capacity below one full set degrades to fully-associative at the
        # available capacity (never to an empty set, which would make
        # access() pop a victim from an empty OrderedDict)
        assoc = min(assoc, capacity_blocks)
        self.n_sets = max(1, capacity_blocks // assoc)
        self.assoc = assoc
        self.sets: list[OrderedDict[int, bool]] = [
            OrderedDict() for _ in range(self.n_sets)]
        self.stats = CacheStats()

    def access(self, block: int, is_write: bool = False) -> bool:
        """Returns True on hit."""
        s = self.sets[block % self.n_sets]
        self.stats.accesses += 1
        if block in s:
            s[block] = s[block] or is_write
            s.move_to_end(block)
            return True
        self.stats.misses += 1
        if len(s) >= self.assoc:
            _victim, dirty = s.popitem(last=False)
            if dirty:
                self.stats.writebacks += 1
        s[block] = is_write
        return False

    def run(self, trace: Iterable[tuple[int, bool]]) -> CacheStats:
        for block, is_write in trace:
            self.access(block, is_write)
        return self.stats


def stack_distance_profile(trace: Sequence[int]) -> list[int]:
    """LRU stack distances for each access (-1 = cold miss).

    O(N * unique) with a movable list; fine for the trace sizes we lower
    (the analytic model handles the big workloads)."""
    stack: list[int] = []
    seen: set[int] = set()
    out: list[int] = []
    for block in trace:
        if block in seen:
            idx = stack.index(block)  # distance from the top
            out.append(idx)
            stack.pop(idx)
        else:
            out.append(-1)
            seen.add(block)
        stack.insert(0, block)
    return out


def misses_at_capacity(distances: Sequence[int], capacity_blocks: int) -> int:
    """Fully-associative LRU misses from a stack-distance profile."""
    return sum(1 for d in distances if d < 0 or d >= capacity_blocks)


def trace_from_streams(streams, block_bytes: int = 4096,
                       max_blocks_per_stream: int = 512) -> list[tuple[int, bool]]:
    """Lower AccessStreams into a concrete interleaved block trace.

    Each stream becomes a region of block ids touched sequentially along a
    byte timeline (the primary pass, streams laid out back to back); a
    stream with finite reuse distance R re-touches each of its blocks R
    bytes of primary traffic after the first touch, so a cache holding more
    than ~R bytes turns the re-touch into a hit — the semantics the
    analytic dram_tx miss curve assigns to R.  Streaming streams (R = inf)
    are touched once and never again.  Approximate by construction — used
    for cross-validating the analytic model on scaled-down workloads."""
    events: list[tuple[float, int, int, bool]] = []  # (byte pos, seq, block, w)
    next_base = 0
    pos = 0.0  # primary-pass byte cursor
    seq = 0
    for s in streams:
        n = min(max_blocks_per_stream,
                max(1, int(s.bytes_total // block_bytes)))
        for block in range(next_base, next_base + n):
            events.append((pos, seq, block, s.is_write))
            seq += 1
            if math.isfinite(s.reuse_distance):
                events.append((pos + s.reuse_distance, seq, block, s.is_write))
                seq += 1
            pos += block_bytes
        next_base += n
    events.sort(key=lambda e: (e[0], e[1]))
    return [(block, is_write) for _, _, block, is_write in events]

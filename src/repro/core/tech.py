"""Technology constants and hardware platform descriptors.

The paper characterizes bitcells in a commercial 16 nm FinFET node and runs
workloads on a GTX 1080 Ti (same node).  We keep the node parameters in one
place so the whole cross-layer stack (mtj -> bitcell -> cachemodel ->
iso-capacity / iso-area) is driven by a single technology definition, and so
a different node can be swapped in (the framework claim of the paper).

Units: seconds, joules, watts, meters**2 (area in mm^2 where noted), bytes.
"""

from __future__ import annotations

import dataclasses
import math

# ---------------------------------------------------------------------------
# 16 nm FinFET node (calibrated to the paper's commercial PDK anchors)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TechNode:
    """Parameters of a logic/memory process node used by the cache model."""

    name: str = "16nm-finfet"
    feature_size_m: float = 16e-9
    vdd: float = 0.8
    # Per-fin drive current and capacitance (order-of-magnitude FinFET
    # values; the absolute scale is calibrated out against Table I/II).
    ion_per_fin_a: float = 42e-6
    ioff_per_fin_a: float = 3e-12   # LP flavor access devices (MRAM cells)
    cgate_per_fin_f: float = 45e-18
    # Wire parasitics per meter for intermediate-level metal.
    wire_res_per_m: float = 3.2e5       # ohm / m
    wire_cap_per_m: float = 2.1e-10     # F / m
    # SRAM bitcell (foundry 6T) — area in um^2; STT/SOT normalized to this.
    sram_cell_area_um2: float = 0.074
    sram_cell_leak_w: float = 2.6e-10   # per-cell leakage at 0.8 V, 25C
    # Sense amplifier offset target used for sensing-delay calculation.
    sense_voltage_v: float = 0.025      # 25 mV bitline split (paper §III-A)


TECH_16NM = TechNode()


# ---------------------------------------------------------------------------
# Platform descriptors (architecture layer)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Platform:
    """Compute platform whose last-level buffer the study replaces."""

    name: str
    peak_flops: float                 # FLOP/s (fp32 for 1080Ti, bf16 for TPU)
    dram_bw: float                    # byte/s
    dram_energy_per_byte: float       # J/byte (off-chip access)
    dram_latency_s: float             # per-transaction latency
    llc_capacity_bytes: int           # shipped last-level buffer capacity
    llc_line_bytes: int               # transaction granularity
    llc_assoc: int
    core_clock_hz: float
    # Fraction of memory-transaction time NOT hidden by compute overlap.
    # Calibrated (see DESIGN.md §8) so SRAM-baseline energy breakdowns match
    # the paper's reported aggregates.
    mem_serialization: float = 0.35


# GTX 1080 Ti — the paper's calibration platform (16 nm, 3 MB L2, 484 GB/s
# GDDR5X, 11.3 TFLOP/s fp32, 1481 MHz base clock; Table IV).
GTX_1080TI = Platform(
    name="gtx-1080ti",
    peak_flops=11.34e12,
    dram_bw=484e9,
    # GDDR5X array + on-die interface energy (the share attributable to the
    # access itself, excluding board/PHY): ~2.5 pJ/bit.  Consistent with the
    # paper's Fig. 4/8 EDP ratios, where DRAM energy is a moderate adder.
    dram_energy_per_byte=20e-12,
    dram_latency_s=180e-9,
    llc_capacity_bytes=3 * 2**20,
    llc_line_bytes=128,
    llc_assoc=16,
    core_clock_hz=1.481e9,
)

# TPU-v5e-class target (the deployment platform for the JAX framework).
# The "LLC" here is the last-level on-chip buffer (VMEM-class capacity).
TPU_V5E = Platform(
    name="tpu-v5e",
    peak_flops=197e12,
    dram_bw=819e9,
    dram_energy_per_byte=80e-12,      # HBM2e ~10 pJ/bit
    dram_latency_s=120e-9,
    llc_capacity_bytes=48 * 2**20,
    llc_line_bytes=128,
    llc_assoc=16,                     # modeled as if HW-managed, see DESIGN
    core_clock_hz=0.94e9,
    mem_serialization=0.35,
)

TPU_ICI_BW = 50e9  # byte/s per link — used by launch/roofline.py


def pj(x: float) -> float:
    """picojoule -> J (readability helper for tables)."""
    return x * 1e-12


def ns(x: float) -> float:
    return x * 1e-9


def mm2_from_um2(x_um2: float) -> float:
    return x_um2 * 1e-6


def clock_cycles(latency_s: float, clock_hz: float) -> int:
    """Convert a latency to (ceil) clock cycles, as the paper does for the
    1080 Ti clock before folding latencies into the runtime model."""
    return max(1, math.ceil(latency_s * clock_hz))

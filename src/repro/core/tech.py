"""Technology constants and hardware platform descriptors.

The paper characterizes bitcells in a commercial 16 nm FinFET node and runs
workloads on a GTX 1080 Ti (same node).  We keep the node parameters in one
place so the whole cross-layer stack (mtj -> bitcell -> cachemodel ->
iso-capacity / iso-area) is driven by a single technology definition, and so
a different node can be swapped in (the framework claim of the paper).

Beyond the calibrated 16 nm anchor, ``scaled_node`` projects the node
parameters to smaller feature sizes with standard post-Dennard scaling
factors (the same first-order rules NVSim's and the Mishty & Sadi DTCO
flow's cross-node projections use), so cross-node DTCO sweeps run on the
same stack: the engine batches TechNodes as a leading tensor axis and the
calibration layer derives non-anchor-node constants from the 16 nm fit
(core/calibration.py documents that rule).

Units: seconds, joules, watts, meters**2 (area in mm^2 where noted), bytes.
"""

from __future__ import annotations

import dataclasses
import re

# ---------------------------------------------------------------------------
# 16 nm FinFET node (calibrated to the paper's commercial PDK anchors)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TechNode:
    """Parameters of a logic/memory process node used by the cache model."""

    name: str = "16nm-finfet"
    feature_size_m: float = 16e-9
    vdd_v: float = 0.8
    # Per-fin drive current (order-of-magnitude FinFET value; the absolute
    # scale is calibrated out against Table I/II).
    ion_per_fin_a: float = 42e-6
    ioff_per_fin_a: float = 3e-12   # LP flavor access devices (MRAM cells)
    # SRAM bitcell (foundry 6T) — area in um^2; STT/SOT normalized to this.
    sram_cell_area_um2: float = 0.074
    # Per-cell 6T storage leakage, calibrated so the EDAP-tuned 3 MB SRAM
    # cache reproduces Table II's 6442 mW (bitcell.sram_bitcell reads this).
    sram_cell_leak_w: float = 2.143e-7
    # Sense amplifier offset target used for sensing-delay calculation.
    sense_voltage_v: float = 0.025      # 25 mV bitline split (paper §III-A)


TECH_16NM = TechNode()


# ---------------------------------------------------------------------------
# Derived nodes: Dennard-style projections from the 16 nm anchor
# ---------------------------------------------------------------------------

# Scaling exponents relative to the anchor: parameter at a scaled node is
# anchor_value * s**exp with s = feature_size / 16 nm (s < 1 for smaller
# nodes).  First-order post-Dennard rules:
#   vdd_v                  weak supply scaling (0.8 V @16 -> ~0.71 V @7)
#   ion_per_fin_a        per-fin drive roughly flat across FinFET nodes
#   ioff_per_fin_a       LP access-device leakage worsens mildly
#   sram_cell_area_um2   classical s^2 geometry scaling
#   sram_cell_leak_w     minimum-size HP 6T cell leakage worsens sharply
#                        (Vt and gate-oxide scaling) — the cross-node SRAM
#                        leakage blow-up the DTCO analysis projects
#   sense_voltage_v      sense margin held constant
SCALING_EXPONENTS = {
    "vdd_v": 0.15,
    "ion_per_fin_a": 0.0,
    "ioff_per_fin_a": -0.5,
    "sram_cell_area_um2": 2.0,
    "sram_cell_leak_w": -1.0,
    "sense_voltage_v": 0.0,
}

# Periphery-fit scaling consumed by the calibration derivation rule
# (calibration.get): logic area follows the node; periphery leakage per MB
# falls slightly (narrower devices, lower vdd_v) despite leakier transistors.
PERI_AREA_EXP = 2.0
PERI_LEAK_EXP = 0.3

# ---------------------------------------------------------------------------
# Device / bitcell / periphery projection exponents
# ---------------------------------------------------------------------------
# One documented exponent per scaled quantity, same convention as
# SCALING_EXPONENTS: value(node) = anchor_value * s**exp.  Ground rules
# follow the SOT-MRAM DTCO study of Mishty & Sadi (arXiv 2303.12310) and
# first-order MTJ scaling physics; every consumer (mtj.device,
# bitcell.characterize, cachemodel.periphery) projects from the calibrated
# 16 nm anchor through exactly one of these tables, so at s = 1 every
# projection is an exact multiply-by-1.0 (bit-identical anchor outputs).

# MTJ compact-model constants (mtj.MTJDevice fields).
#   ic0:    STT critical current is retention-pinned — the thermal stability
#           factor Delta must hold, so Ic0 barely falls with the cell (the
#           STT scaling wall); SOT's Ic0 tracks the heavy-metal track
#           cross-section and falls steeply (the DTCO study's headline).
#   tau:    precessional time constant follows the free-layer moment.
#   r_*:    junction/track resistance rises as the area shrinks at roughly
#           constant RA product (partially thinned at advanced nodes).
#   sense_time: TMR read window erodes slowly with junction scaling.
MTJ_SCALING_EXPONENTS = {
    "stt": dict(ic0_set_a=0.05, ic0_reset_a=0.05,
                tau_set_s=1.0, tau_reset_s=1.0,
                r_set_ohm=-1.0, r_reset_ohm=-1.0, r_read_ohm=-1.0,
                sense_time_s=-0.15),
    "sot": dict(ic0_set_a=0.6, ic0_reset_a=0.6,
                tau_set_s=1.0, tau_reset_s=1.0,
                r_set_ohm=-1.0, r_reset_ohm=-1.0, r_read_ohm=-1.0,
                sense_time_s=-0.15),
}

# Bitcell-level constants (bitcell.py).
#   i_read/i_write_per_fin:  MRAM access-path drive derates with vdd_v — the
#       write path must hold vdd_v headroom across the MTJ stack, eroding as
#       the supply scales (the infeasibility mechanism at deep nodes).
#   area_base:  the MTJ pillar + BEOL keep-out is via/metal-pitch limited
#       and shrinks slower than the 6T footprint, so the SRAM-normalized
#       base term *grows* at smaller nodes (density advantage erodes — the
#       cross-node iso-area capacity trend).
#   area_per_fin:  access fins are front-end devices scaling with the node
#       like the 6T cell, so their normalized contribution is flat.
#   sram_t_rw / sram_e_rw:  intrinsic 6T CV/I time and CV^2 energy.
BITCELL_SCALING_EXPONENTS = {
    "i_read_per_fin": 0.15,
    "i_write_per_fin": 0.15,
    "area_base": -0.25,
    "area_per_fin": 0.0,
    "sram_t_rw": 1.15,
    "sram_e_rw": 1.3,
}

# Periphery building blocks (cachemodel.Periphery fields).
#   t_gate_s:      FO4 delay ~ C*V/I_drive (C and V fall, drive per um flat).
#   t_sense_amp_s: latch resolve ~ C/gm.
#   e_gate_j:      CV^2 per switched gate.
#   htree_ns_per_mm:  repeated-wire delay per mm worsens as wire RC blows
#       up faster than repeaters improve (partially recovered by vdd_v/gate
#       gains — the classic interconnect-dominated regime).
#   htree_pj_per_mm_bit:  wire energy per mm*bit ~ C_wire * V^2 (per-mm
#       wire cap roughly flat, V^2 falls).
#   c_bitline/c_wordline:  per-cell wire capacitance tracks the cell pitch.
PERIPHERY_SCALING_EXPONENTS = {
    "t_gate_s": 1.15,
    "t_sense_amp_s": 1.0,
    "e_gate_j": 1.3,
    "htree_ns_per_mm": -0.5,
    "htree_pj_per_mm_bit": 0.3,
    "c_bitline_per_row_f": 1.0,
    "c_wordline_per_col_f": 1.0,
}

# Validated projection range.  The exponent tables above are first-order
# fits anchored at 16 nm and sanity-checked against the published 7 nm DTCO
# ground rules; below 7 nm (gate-all-around territory, different MTJ
# integration) they are extrapolation without evidence, so ``scaled_node``
# refuses unless explicitly overridden.
MIN_FEATURE_SIZE_M = 7e-9


def scale_factor(node: TechNode) -> float:
    """Linear feature-size factor s of `node` relative to the 16 nm anchor."""
    return node.feature_size_m / TECH_16NM.feature_size_m


def scaled_node(feature_size_m: float, name: str | None = None,
                allow_extrapolation: bool = False) -> TechNode:
    """Project the calibrated 16 nm anchor to another feature size.

    Applies the SCALING_EXPONENTS rules to every node parameter.  Nodes
    built here (and only these — plus the anchor itself) have a calibration
    derivation rule; ``calibration.get`` raises for hand-crafted nodes.

    Projection targets below ``MIN_FEATURE_SIZE_M`` (the validated 7–16 nm
    range) raise unless ``allow_extrapolation=True`` — the exponent tables
    have no evidence beyond 7 nm and extrapolating silently is exactly the
    cross-node failure mode the derivation rules exist to prevent.
    """
    if feature_size_m < MIN_FEATURE_SIZE_M and not allow_extrapolation:
        raise ValueError(
            f"feature size {feature_size_m * 1e9:g} nm is below the "
            f"validated projection range ({MIN_FEATURE_SIZE_M * 1e9:g}–"
            f"{TECH_16NM.feature_size_m * 1e9:g} nm): the scaling exponents "
            "are fitted to 16 nm anchors and published 7 nm ground rules "
            "only; pass allow_extrapolation=True to project anyway")
    s = feature_size_m / TECH_16NM.feature_size_m
    label = name if name is not None else f"{feature_size_m * 1e9:g}nm-scaled"
    return TechNode(
        name=label,
        feature_size_m=feature_size_m,
        **{f: getattr(TECH_16NM, f) * s ** e
           for f, e in SCALING_EXPONENTS.items()},
    )


# Standard DTCO projection targets (12/10/7 nm), per the cross-node sweep.
TECH_12NM = scaled_node(12e-9)
TECH_10NM = scaled_node(10e-9)
TECH_7NM = scaled_node(7e-9)


# ---------------------------------------------------------------------------
# Node registry — symbolic name -> TechNode (SweepSpec v2 resolution)
# ---------------------------------------------------------------------------

# Canonical names of the prebuilt nodes.  ``node()`` additionally resolves
# any "<feature>nm" spelling through ``scaled_node`` (those are exactly the
# nodes that carry a calibration derivation rule), so a JSON spec can name
# an arbitrary projection target without touching Python.
NODES = {n.name: n for n in (TECH_16NM, TECH_12NM, TECH_10NM, TECH_7NM)}

_NODE_NAME_RE = re.compile(r"(\d+(?:\.\d+)?)nm(?:-scaled|-finfet)?\Z")


def node(name: str) -> TechNode:
    """Resolve a symbolic node name: a canonical registry name
    ("16nm-finfet", "7nm-scaled"), or any "<feature>nm" shorthand within the
    validated projection range, which maps to the anchor at 16 nm and to
    ``scaled_node`` otherwise.  Shorthands below ``MIN_FEATURE_SIZE_M``
    raise — a symbolic spec has no extrapolation override by design."""
    if name in NODES:
        return NODES[name]
    m = _NODE_NAME_RE.fullmatch(name)
    if m:
        # match registered nodes by their printed feature size first, so
        # "7nm" is exactly TECH_7NM (float(7) * 1e-9 != 7e-9 in binary)
        for n in NODES.values():
            if f"{n.feature_size_m * 1e9:g}" == m.group(1):
                return n
        feature_m = float(m.group(1)) * 1e-9
        if feature_m < MIN_FEATURE_SIZE_M:
            raise ValueError(
                f"technology node {name!r} is below the validated "
                f"{MIN_FEATURE_SIZE_M * 1e9:g}–"
                f"{TECH_16NM.feature_size_m * 1e9:g} nm projection range; "
                "symbolic specs cannot extrapolate (build such a node "
                "explicitly with tech.scaled_node(..., "
                "allow_extrapolation=True) if you really mean it)")
        return scaled_node(feature_m)
    raise ValueError(f"unknown technology node {name!r}; canonical names: "
                     f"{sorted(NODES)} (or any '<feature>nm' shorthand)")


# ---------------------------------------------------------------------------
# Platform descriptors (architecture layer)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Platform:
    """Compute platform whose last-level buffer the study replaces."""

    name: str
    peak_flops: float                 # FLOP/s (fp32 for 1080Ti, bf16 for TPU)
    dram_bw: float                    # byte/s
    dram_energy_per_byte: float       # J/byte (off-chip access)
    dram_latency_s: float             # per-transaction latency
    llc_capacity_bytes: int           # shipped last-level buffer capacity
    llc_line_bytes: int               # transaction granularity
    llc_assoc: int
    core_clock_hz: float
    # Fraction of memory-transaction time NOT hidden by compute overlap.
    # Calibrated (see DESIGN.md §8) so SRAM-baseline energy breakdowns match
    # the paper's reported aggregates.
    mem_serialization: float = 0.35


# GTX 1080 Ti — the paper's calibration platform (16 nm, 3 MB L2, 484 GB/s
# GDDR5X, 11.3 TFLOP/s fp32, 1481 MHz base clock; Table IV).
GTX_1080TI = Platform(
    name="gtx-1080ti",
    peak_flops=11.34e12,
    dram_bw=484e9,
    # GDDR5X array + on-die interface energy (the share attributable to the
    # access itself, excluding board/PHY): ~2.5 pJ/bit.  Consistent with the
    # paper's Fig. 4/8 EDP ratios, where DRAM energy is a moderate adder.
    dram_energy_per_byte=20e-12,
    dram_latency_s=180e-9,
    llc_capacity_bytes=3 * 2**20,
    llc_line_bytes=128,
    llc_assoc=16,
    core_clock_hz=1.481e9,
)

# TPU-v5e-class target (the deployment platform for the JAX framework).
# The "LLC" here is the last-level on-chip buffer (VMEM-class capacity).
TPU_V5E = Platform(
    name="tpu-v5e",
    peak_flops=197e12,
    dram_bw=819e9,
    dram_energy_per_byte=80e-12,      # HBM2e ~10 pJ/bit
    dram_latency_s=120e-9,
    llc_capacity_bytes=48 * 2**20,
    llc_line_bytes=128,
    llc_assoc=16,                     # modeled as if HW-managed, see DESIGN
    core_clock_hz=0.94e9,
    mem_serialization=0.35,
)

TPU_ICI_BW = 50e9  # byte/s per link — used by launch/roofline.py


# ---------------------------------------------------------------------------
# Platform registry — symbolic name -> Platform (SweepSpec v2 resolution)
# ---------------------------------------------------------------------------

PLATFORMS = {p.name: p for p in (GTX_1080TI, TPU_V5E)}


def platform(name: str) -> Platform:
    """Resolve a symbolic platform name through the registry."""
    try:
        return PLATFORMS[name]
    except KeyError:
        raise ValueError(f"unknown platform {name!r}; available: "
                         f"{sorted(PLATFORMS)}") from None


def pj(x: float) -> float:
    """picojoule -> J (readability helper for tables)."""
    return x * 1e-12


def ns(x: float) -> float:
    return x * 1e-9


def mm2_from_um2(x_um2: float) -> float:
    return x_um2 * 1e-6

"""DeepNVM++ — cross-layer NVM cache modeling framework (the paper's core).

Layers (paper Fig. 2):
    mtj / bitcell      circuit-level device characterization   (Table I)
    cachemodel / tuner NVSim-style cache design + Alg. 1       (Table II)
    engine             ... the circuit sweep as one batched computation
    workloads / traffic DL workload memory statistics          (SIII-C)
    workload_engine    ... the workload fold as one batched computation
    cachesim           trace/analytic DRAM model               (SIII-D)
    sweep              one declarative SweepSpec driving both engines
                       (+ the symbolic, JSON-round-trippable v2 form)
    dse                Pareto fronts / capacity plateaus on SweepResults
    isocap / isoarea / scaling   architecture-level analyses   (Figs 3-10)
    dtco               cross-node DTCO sweep on the batched node axis
"""

from repro.core import (  # noqa: F401
    bitcell,
    cachemodel,
    cachesim,
    calibration,
    dse,
    dtco,
    engine,
    isoarea,
    isocap,
    mtj,
    report,
    scaling,
    sweep,
    tech,
    traffic,
    tuner,
    workload_engine,
    workloads,
)

"""DNVM004 — lock discipline in the concurrent service layer.

A class that creates a ``threading.Lock``/``RLock``/``Condition`` in
``__init__`` owns shared mutable state; every mutation of its instance
attributes outside ``__init__`` must happen under ``with self._lock:``
(any of the class's own locks counts — this pass checks *guardedness*,
not lock-to-field assignment).  The same applies at module scope: a
module-level lock means module globals assigned inside functions must
hold it.

This is the PR-8 bug class: the sweep service's coalescer/stat counters
are read concurrently by ``stats()`` transports while the worker thread
increments them — an unlocked ``self.batches += 1`` is a data race that
no test reliably catches.
"""

from __future__ import annotations

import ast

from repro.analysis.common import Finding, ModuleInfo, dotted

RULE = "DNVM004"

_LOCK_FACTORIES = frozenset({
    "threading.Lock", "threading.RLock", "threading.Condition",
    "Lock", "RLock", "Condition",
})


def check(mod: ModuleInfo) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            findings += _check_class(mod, node)
    findings += _check_module_globals(mod)
    return findings


# ---------------------------------------------------------------------------
# class-attribute discipline


def _check_class(mod: ModuleInfo, cls: ast.ClassDef) -> list[Finding]:
    lock_attrs = _owned_locks(cls)
    if not lock_attrs:
        return []
    out: list[Finding] = []
    for item in cls.body:
        if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if item.name == "__init__":
            continue
        self_name = _self_param(item)
        if self_name is None:
            continue
        for target, stmt in _self_mutations(item, self_name):
            if target.attr in lock_attrs:
                continue
            if _under_owned_lock(stmt, self_name, lock_attrs):
                continue
            out.append(Finding(
                mod.path, stmt.lineno, RULE,
                f"'{cls.name}.{item.name}' mutates "
                f"'self.{target.attr}' outside "
                f"'with self.{sorted(lock_attrs)[0]}' — "
                f"{cls.name} owns lock(s) {sorted(lock_attrs)}",
                mod.scope_of(stmt)))
    return out


def _owned_locks(cls: ast.ClassDef) -> set[str]:
    """Attributes assigned a Lock/RLock/Condition anywhere in the class
    (normally ``__init__``)."""
    locks: set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if not (isinstance(node.value, ast.Call)
                and dotted(node.value.func) in _LOCK_FACTORIES):
            continue
        for t in node.targets:
            if (isinstance(t, ast.Attribute)
                    and isinstance(t.value, ast.Name)):
                locks.add(t.attr)
    return locks


def _self_param(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> str | None:
    args = fn.args.posonlyargs + fn.args.args
    return args[0].arg if args else None


def _self_mutations(fn: ast.FunctionDef | ast.AsyncFunctionDef,
                    self_name: str):
    """(attribute-target, owning-statement) pairs for every ``self.x``
    store — plain/augmented assignment, ``del``, and in-place container
    mutation (``self.x[k] = ...``, ``del self.x[k]``)."""
    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                attr = _self_attr(t, self_name)
                if attr is not None:
                    yield attr, node
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                attr = _self_attr(t, self_name)
                if attr is not None:
                    yield attr, node


def _self_attr(target: ast.expr, self_name: str) -> ast.Attribute | None:
    """The ``self.x`` attribute mutated by this store target, unwrapping
    subscripts (``self.x[k] = v`` mutates ``self.x``) and tuples."""
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            found = _self_attr(elt, self_name)
            if found is not None:
                return found
        return None
    while isinstance(target, ast.Subscript):
        target = target.value
    if (isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == self_name):
        return target
    return None


def _under_owned_lock(node: ast.AST, self_name: str,
                      lock_attrs: set[str]) -> bool:
    cur = getattr(node, "_dnvm_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.With, ast.AsyncWith)):
            for item in cur.items:
                expr = item.context_expr
                # unwrap condition helpers: self._cv, self._lock
                if (isinstance(expr, ast.Attribute)
                        and isinstance(expr.value, ast.Name)
                        and expr.value.id == self_name
                        and expr.attr in lock_attrs):
                    return True
        cur = getattr(cur, "_dnvm_parent", None)
    return False


# ---------------------------------------------------------------------------
# module-global discipline


def _check_module_globals(mod: ModuleInfo) -> list[Finding]:
    module_locks = _module_locks(mod.tree)
    if not module_locks:
        return []
    out: list[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Global):
            continue
        fn = getattr(node, "_dnvm_parent", None)
        while fn is not None and not isinstance(
                fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn = getattr(fn, "_dnvm_parent", None)
        if fn is None:
            continue
        declared = set(node.names)
        for sub in ast.walk(fn):
            if not isinstance(sub, (ast.Assign, ast.AugAssign)):
                continue
            targets = sub.targets if isinstance(sub, ast.Assign) \
                else [sub.target]
            names = {t.id for t in targets if isinstance(t, ast.Name)}
            hit = names & declared
            if hit and not _under_module_lock(sub, module_locks):
                out.append(Finding(
                    mod.path, sub.lineno, RULE,
                    f"global '{sorted(hit)[0]}' assigned outside "
                    f"'with {sorted(module_locks)[0]}' — module owns "
                    f"lock(s) {sorted(module_locks)}",
                    mod.scope_of(sub)))
    return out


def _module_locks(tree: ast.Module) -> set[str]:
    locks: set[str] = set()
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)
                and dotted(node.value.func) in _LOCK_FACTORIES):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    locks.add(t.id)
    return locks


def _under_module_lock(node: ast.AST, locks: set[str]) -> bool:
    cur = getattr(node, "_dnvm_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.With, ast.AsyncWith)):
            for item in cur.items:
                if (isinstance(item.context_expr, ast.Name)
                        and item.context_expr.id in locks):
                    return True
        cur = getattr(cur, "_dnvm_parent", None)
    return False

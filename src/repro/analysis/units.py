"""DNVM003 — unit consistency of the PPA arithmetic.

The repo's quantity-bearing names carry their unit as a trailing
suffix (``read_latency_s``, ``sense_energy_j``, ``c_bitline_per_row_f``,
``htree_ns_per_mm``); a handful of registered names (``vdd``, ``rows``,
``peri_area_lin``…) carry dimensions the suffix grammar can't express.
This pass propagates dimensions — exponent vectors over (m, kg, s, A),
*scale-free* so ``ns`` and ``s`` are both time — through the PPA
expressions and flags:

- adding/subtracting/ordering two quantities of different dimensions
  (seconds + joules is the canonical error);
- binding a known dimension to a name whose suffix declares a
  different one (``_f * _ohm`` assigned to an ``_s`` name is *checked
  and accepted*: F·Ω = s);
- passing a known dimension to a keyword argument or returning it from
  a function whose name declares a different one.

Numeric literals are polymorphic coefficients (``* 1e-9`` scale factors
never conflict); unparseable names are unknowns that absorb silently —
so the pass only speaks when both sides of an operation are genuinely
known, which keeps it quiet outside the unit-disciplined core.
"""

from __future__ import annotations

import ast

from repro.analysis.common import Finding, ModuleInfo, dotted, func_params

RULE = "DNVM003"

# Dimension: exponent 4-vector over (m, kg, s, A), or one of the
# sentinels below.  Scale-free: ns == s, um2 == m2.
Dim = tuple[float, float, float, float]
UNKNOWN = None          # no information — absorbs every operation
ANY = "any"             # numeric literal — unifies with anything

ONE: Dim = (0.0, 0.0, 0.0, 0.0)
L: Dim = (1, 0, 0, 0)
M: Dim = (0, 1, 0, 0)
T: Dim = (0, 0, 1, 0)
I: Dim = (0, 0, 0, 1)  # noqa: E741 - SI symbol for current
AREA: Dim = (2, 0, 0, 0)
VOLT: Dim = (2, 1, -3, -1)
WATT: Dim = (2, 1, -3, 0)
JOULE: Dim = (2, 1, -2, 0)
FARAD: Dim = (-2, -1, 4, 2)
OHM: Dim = (2, 1, -3, -2)
HERTZ: Dim = (0, 0, -1, 0)

_NAMED = {
    ONE: "1", L: "m", AREA: "m^2", T: "s", M: "kg", I: "A", VOLT: "V",
    WATT: "W", JOULE: "J", FARAD: "F", OHM: "ohm", HERTZ: "1/s",
    (1, 1, -3, 0): "W/m", (-1, 1, -2, 0): "J/m", (-1, 0, 1, 0): "s/m",
    (0, 0, 1, 1): "C",
}

# Suffix tokens — trailing ``_``-separated unit tokens of a name.
# Grammar: UNIT+ ("per" UNIT+)* anchored at the end of the name; a run
# that would *start* with "per" (``energy_per_byte``) leaves the
# numerator quantity unparsed and falls back to the registry.
_TOKENS: dict[str, Dim] = {}
for _t in ("s", "ns", "ps", "us", "ms"):
    _TOKENS[_t] = T
for _t in ("w", "mw", "uw", "nw", "pw"):
    _TOKENS[_t] = WATT
for _t in ("j", "pj", "nj", "fj", "aj", "uj", "mj"):
    _TOKENS[_t] = JOULE
for _t in ("f", "ff", "pf", "af"):
    _TOKENS[_t] = FARAD
for _t in ("ohm", "kohm", "mohm"):
    _TOKENS[_t] = OHM
for _t in ("a", "ma", "ua", "na", "pa"):
    _TOKENS[_t] = I
for _t in ("v", "mv", "uv"):
    _TOKENS[_t] = VOLT
for _t in ("m", "mm", "um", "nm", "cm"):
    _TOKENS[_t] = L
for _t in ("m2", "mm2", "um2", "nm2", "area"):
    _TOKENS[_t] = AREA
for _t in ("hz", "khz", "mhz", "ghz"):
    _TOKENS[_t] = HERTZ
# information/count tokens are dimensionless: scale-free analysis can't
# distinguish bits from bytes from counts anyway, and the PPA code
# freely multiplies per-bit energies by bit counts.
for _t in ("bit", "bits", "byte", "bytes", "kb", "mb", "gb", "tb",
           "fin", "fins", "norm", "frac", "ratio", "rel", "pct"):
    _TOKENS[_t] = ONE

# Exact-name registry (leading underscores stripped, lowercased): the
# tech/calibration/Platform/org fields whose dimension the suffix
# grammar cannot express.
REGISTRY: dict[str, Dim] = {
    # electrical
    "vdd": VOLT,
    "ion_per_fin_a": I, "ioff_per_fin_a": I, "i_read_per_fin": I,
    # calibration fits (scale-free: "per sqrt(MB)" is dimensionless)
    "peri_area_lin": AREA, "peri_area_sqrt": AREA,
    "leak_lin": WATT, "leak_sqrt": WATT,
    "k_read_lat": ONE, "k_write_lat": ONE, "k_read_e": ONE,
    "k_write_e": ONE,
    # platform
    "peak_flops": HERTZ, "dram_bw": HERTZ,  # byte/s, info dimensionless
    "dram_energy_per_byte": JOULE, "mem_serialization": ONE,
    "llc_assoc": ONE,
    # organization / counts
    "rows": ONE, "cols": ONE, "banks": ONE, "assoc": ONE, "ways": ONE,
    "ways_sensed": ONE, "fins_read": ONE, "fins_write": ONE,
    "total_fins": ONE, "flips": ONE, "n_sub": ONE, "batch": ONE,
    "reuse_distance": ONE,
}


def render(dim: Dim) -> str:
    if dim in _NAMED:
        return _NAMED[dim]
    parts = []
    for sym, e in zip(("m", "kg", "s", "A"), dim):
        if e:
            parts.append(sym if e == 1 else
                         f"{sym}^{e:g}")
    return "*".join(parts) or "1"


def suffix_dim(name: str) -> Dim | None:
    """Dimension declared by a name's trailing unit-token run, or None."""
    tokens = [t for t in name.lower().lstrip("_").split("_") if t]
    run: list[str] = []
    for tok in reversed(tokens):
        if tok in _TOKENS or tok == "per":
            run.append(tok)
        else:
            break
    run.reverse()
    if not run or run[0] == "per" or run[-1] == "per":
        return None
    if len(run) == len(tokens):
        # no quantity stem: bare locals like ``s``/``f``/``bits`` are
        # loop/scale variables, not suffixed quantities
        return None
    groups: list[list[Dim]] = [[]]
    for tok in run:
        if tok == "per":
            groups.append([])
        else:
            groups[-1].append(_TOKENS[tok])
    # numerator: the *last* token wins — earlier numerator tokens are
    # quantity descriptors ("area_mm2" is an area in mm^2, not
    # area*mm^2); denominator groups multiply ("per_mm_bit").
    dim = groups[0][-1]
    for grp in groups[1:]:
        for d in grp:
            dim = _div(dim, d)
    return dim


def declared_dim(name: str) -> Dim | None:
    key = name.lower().lstrip("_")
    if key in REGISTRY:
        return REGISTRY[key]
    return suffix_dim(name)


def _mul(a: Dim, b: Dim) -> Dim:
    return tuple(x + y for x, y in zip(a, b))  # type: ignore[return-value]


def _div(a: Dim, b: Dim) -> Dim:
    return tuple(x - y for x, y in zip(a, b))  # type: ignore[return-value]


def _pow(a: Dim, n: float) -> Dim:
    return tuple(x * n for x in a)  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# expression evaluation


_SQRT_FNS = frozenset({"sqrt", "math.sqrt", "np.sqrt", "jnp.sqrt",
                       "numpy.sqrt", "jax.numpy.sqrt"})
_DIMLESS_FNS = frozenset({
    "log", "log2", "log10", "exp", "tanh", "math.log", "math.log2",
    "math.log10", "math.exp", "math.tanh", "np.log", "np.log2", "np.exp",
    "jnp.log", "jnp.log2", "jnp.exp", "len", "math.isfinite", "bool",
})
_PASSTHROUGH_FNS = frozenset({
    "float", "int", "abs", "round", "sum", "math.ceil", "math.floor",
    "math.fabs", "np.ceil", "np.floor", "np.abs", "np.sum", "np.mean",
    "jnp.ceil", "jnp.floor", "jnp.abs", "jnp.sum", "jnp.mean",
    "np.asarray", "jnp.asarray", "np.array", "jnp.array",
})
_MERGE_FNS = frozenset({
    "min", "max", "np.minimum", "np.maximum", "jnp.minimum",
    "jnp.maximum",
})


class _UnitChecker:
    def __init__(self, mod: ModuleInfo):
        self.mod = mod
        self.findings: list[Finding] = []

    # -- entry ---------------------------------------------------------------

    def check_function(self, fn: ast.FunctionDef | ast.AsyncFunctionDef
                       ) -> None:
        env: dict[str, object] = {}
        for p in func_params(fn):
            d = declared_dim(p)
            if d is not None:
                env[p] = d
        self._stmts(fn.body, env, fn)

    # -- statements ----------------------------------------------------------

    def _stmts(self, body: list[ast.stmt], env: dict, fn) -> None:
        for stmt in body:
            self._stmt(stmt, env, fn)

    def _stmt(self, stmt: ast.stmt, env: dict, fn) -> None:
        if isinstance(stmt, ast.Assign):
            dim = self.dim_of(stmt.value, env)
            for t in stmt.targets:
                self._bind(t, dim, env)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            dim = self.dim_of(stmt.value, env)
            self._bind(stmt.target, dim, env)
        elif isinstance(stmt, ast.AugAssign):
            cur = self._load_target(stmt.target, env)
            inc = self.dim_of(stmt.value, env)
            merged = self._merge(cur, inc, stmt, "augmented assignment")
            self._bind(stmt.target, merged, env, check=False)
        elif isinstance(stmt, ast.Return) and stmt.value is not None:
            dim = self.dim_of(stmt.value, env)
            declared = suffix_dim(fn.name)
            if (declared is not None and isinstance(dim, tuple)
                    and dim != declared):
                self._flag(stmt, f"returns {render(dim)} from "
                           f"'{fn.name}' which declares "
                           f"{render(declared)}")
        elif isinstance(stmt, ast.Expr):
            self.dim_of(stmt.value, env)
        elif isinstance(stmt, (ast.If,)):
            self.dim_of(stmt.test, env)
            self._stmts(stmt.body, env, fn)
            self._stmts(stmt.orelse, env, fn)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._bind(stmt.target, UNKNOWN, env, check=False)
            self._stmts(stmt.body, env, fn)
            self._stmts(stmt.orelse, env, fn)
        elif isinstance(stmt, ast.While):
            self.dim_of(stmt.test, env)
            self._stmts(stmt.body, env, fn)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            self._stmts(stmt.body, env, fn)
        elif isinstance(stmt, ast.Try):
            self._stmts(stmt.body, env, fn)
            for h in stmt.handlers:
                self._stmts(h.body, env, fn)
            self._stmts(stmt.orelse, env, fn)
            self._stmts(stmt.finalbody, env, fn)
        # nested defs/classes: handled as their own functions by check()

    def _bind(self, target: ast.expr, dim, env: dict,
              check: bool = True) -> None:
        if isinstance(target, ast.Name):
            name = target.id
        elif isinstance(target, ast.Attribute):
            name = target.attr
        else:
            for elt in getattr(target, "elts", ()):
                self._bind(elt, UNKNOWN, env, check=False)
            return
        declared = declared_dim(name)
        if (check and declared is not None and isinstance(dim, tuple)
                and dim != declared):
            self._flag(target, f"binds {render(dim)} to '{name}' which "
                       f"declares {render(declared)}")
        if isinstance(target, ast.Name):
            if isinstance(dim, tuple):
                env[name] = dim
            elif declared is not None:
                env[name] = declared  # trust the suffix when value unknown
            else:
                env[name] = dim

    def _load_target(self, target: ast.expr, env: dict):
        if isinstance(target, ast.Name):
            return env.get(target.id, declared_dim(target.id) or UNKNOWN)
        if isinstance(target, ast.Attribute):
            return declared_dim(target.attr) or UNKNOWN
        return UNKNOWN

    # -- expressions ---------------------------------------------------------

    def dim_of(self, node: ast.expr, env: dict):
        if isinstance(node, ast.Constant):
            return ANY if isinstance(node.value, (int, float, complex)) \
                else UNKNOWN
        if isinstance(node, ast.Name):
            if node.id in env:
                return env[node.id]
            d = declared_dim(node.id)
            return d if d is not None else UNKNOWN
        if isinstance(node, ast.Attribute):
            d = declared_dim(node.attr)
            return d if d is not None else UNKNOWN
        if isinstance(node, ast.BinOp):
            return self._binop(node, env)
        if isinstance(node, ast.UnaryOp):
            return self.dim_of(node.operand, env)
        if isinstance(node, ast.Compare):
            return self._compare(node, env)
        if isinstance(node, ast.Call):
            return self._call(node, env)
        if isinstance(node, ast.IfExp):
            self.dim_of(node.test, env)
            return self._merge(self.dim_of(node.body, env),
                               self.dim_of(node.orelse, env),
                               node, "conditional branches")
        if isinstance(node, ast.Subscript):
            base = self.dim_of(node.value, env)
            return base if isinstance(base, tuple) else UNKNOWN
        if isinstance(node, ast.Dict):
            vals = [self.dim_of(v, env) for v in node.values
                    if v is not None]
            if vals and all(v == ANY or v == ONE for v in vals):
                return ONE
            return UNKNOWN
        return UNKNOWN

    def _binop(self, node: ast.BinOp, env: dict):
        left = self.dim_of(node.left, env)
        right = self.dim_of(node.right, env)
        op = node.op
        if isinstance(op, ast.Mult):
            return self._combine(left, right, _mul)
        if isinstance(op, (ast.Div, ast.FloorDiv)):
            return self._combine(left, right, _div)
        if isinstance(op, (ast.Add, ast.Sub)):
            what = "+" if isinstance(op, ast.Add) else "-"
            return self._merge(left, right, node, f"'{what}' operands")
        if isinstance(op, ast.Pow):
            if left == ANY or left == ONE:
                return left if left == ONE else ANY
            if isinstance(left, tuple):
                if (isinstance(node.right, ast.Constant)
                        and isinstance(node.right.value, (int, float))):
                    return _pow(left, float(node.right.value))
                if (isinstance(node.right, ast.UnaryOp)
                        and isinstance(node.right.op, ast.USub)
                        and isinstance(node.right.operand, ast.Constant)):
                    return _pow(left, -float(node.right.operand.value))
            return UNKNOWN
        return UNKNOWN

    def _compare(self, node: ast.Compare, env: dict):
        dims = [self.dim_of(node.left, env)]
        dims += [self.dim_of(c, env) for c in node.comparators]
        ordered = [isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE))
                   for op in node.ops]
        for i, is_ord in enumerate(ordered):
            a, b = dims[i], dims[i + 1]
            if (is_ord and isinstance(a, tuple) and isinstance(b, tuple)
                    and a != b):
                self._flag(node, f"compares {render(a)} against "
                           f"{render(b)}")
        return ONE

    def _call(self, node: ast.Call, env: dict):
        for arg in node.args:
            self.dim_of(arg, env)
        for kw in node.keywords:
            if kw.arg is None:
                continue
            declared = declared_dim(kw.arg)
            val = self.dim_of(kw.value, env)
            if (declared is not None and isinstance(val, tuple)
                    and val != declared):
                self._flag(kw.value, f"passes {render(val)} as keyword "
                           f"'{kw.arg}' which declares "
                           f"{render(declared)}")
        name = dotted(node.func)
        short = (name or "").rsplit(".", 1)[-1] if name else ""
        attr_name = node.func.attr if isinstance(node.func, ast.Attribute) \
            else (name or "")
        if name in _SQRT_FNS:
            d = self.dim_of(node.args[0], env) if node.args else UNKNOWN
            return _pow(d, 0.5) if isinstance(d, tuple) else d
        if name in _DIMLESS_FNS or short in ("log", "log2", "exp"):
            return ONE
        if name in _PASSTHROUGH_FNS:
            return self.dim_of(node.args[0], env) if node.args else UNKNOWN
        if name in _MERGE_FNS or short in ("minimum", "maximum"):
            out = ANY
            for a in node.args:
                out = self._merge(out, self.dim_of(a, env), node,
                                  f"'{short or name}' arguments")
            return out
        if short in ("where", "clip"):
            out = ANY
            for a in node.args[1:]:
                out = self._merge(out, self.dim_of(a, env), node,
                                  f"'{short}' branches")
            return out
        # a callee whose *name* carries a unit suffix declares its result
        d = suffix_dim(attr_name)
        return d if d is not None else UNKNOWN

    def _combine(self, a, b, op):
        if a == ANY:
            return b
        if b == ANY:
            return a
        if isinstance(a, tuple) and isinstance(b, tuple):
            return op(a, b)
        return UNKNOWN

    def _merge(self, a, b, node: ast.AST, what: str):
        if isinstance(a, tuple) and isinstance(b, tuple):
            if a != b:
                self._flag(node, f"unit mismatch: {what} are "
                           f"{render(a)} and {render(b)}")
                return UNKNOWN
            return a
        if a == ANY:
            return b
        if b == ANY:
            return a
        return UNKNOWN

    def _flag(self, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(
            self.mod.path, getattr(node, "lineno", 1), RULE, message,
            self.mod.scope_of(node)))


def check(mod: ModuleInfo) -> list[Finding]:
    checker = _UnitChecker(mod)
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            checker.check_function(node)
    # deduplicate: nested defs are visited both standalone and (not) by
    # the statement walker; identical findings collapse.
    return sorted(set(checker.findings))

"""Pass orchestration: parse once, run every rule, apply suppressions
and the baseline, and report."""

from __future__ import annotations

import dataclasses

from repro.analysis import locks, memo_keys, retrace, units
from repro.analysis.common import Finding, load_module, walk_python_files

CHECKS = {
    "DNVM001": memo_keys.check,
    "DNVM002": retrace.check,
    "DNVM003": units.check,
    "DNVM004": locks.check,
}


@dataclasses.dataclass
class RunResult:
    findings: list[Finding]          # everything a rule raised
    active: list[Finding]            # minus suppressions and baseline
    suppressed: int
    baselined: int
    files: int

    @property
    def counts(self) -> dict[str, int]:
        out = {rule: 0 for rule in CHECKS}
        for f in self.active:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out


def run_paths(paths: list[str], rules: list[str] | None = None,
              baseline: set[str] | None = None) -> RunResult:
    selected = {r: CHECKS[r] for r in (rules or CHECKS)}
    files = walk_python_files(paths)
    findings: list[Finding] = []
    suppressed = 0
    for path in files:
        try:
            mod = load_module(path)
        except (SyntaxError, ValueError) as e:
            findings.append(Finding(path, _lineno_of(e), "DNVM000",
                                    str(e), "<parse>"))
            continue
        for rule, fn in selected.items():
            for f in fn(mod):
                if rule in mod.suppressions.get(f.line, set()):
                    suppressed += 1
                else:
                    findings.append(f)
    findings.sort()
    baseline = baseline or set()
    active = [f for f in findings if f.baseline_key() not in baseline]
    return RunResult(findings=findings, active=active,
                     suppressed=suppressed,
                     baselined=len(findings) - len(active),
                     files=len(files))


def _lineno_of(e: Exception) -> int:
    if isinstance(e, SyntaxError) and e.lineno:
        return e.lineno
    return 1

"""Shared infrastructure for the repro.analysis passes.

A pass is a function ``check(module: ModuleInfo) -> list[Finding]``.
``ModuleInfo`` bundles the parsed AST (with parent links), the source
lines (for suppression comments), and module-level facts every pass
needs — most importantly which module-level names are *varying state*
(reassigned or mutated after their first binding) as opposed to
assign-once constants.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import tokenize
from typing import Iterable, Iterator

BASELINE_DEFAULT = "analysis-baseline.txt"

# Method names whose call on a bare name counts as mutating it.
_MUTATORS = frozenset({
    "append", "extend", "insert", "add", "update", "setdefault", "pop",
    "popitem", "remove", "discard", "clear", "appendleft",
})


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One ``file:line RULE message`` diagnostic."""

    path: str
    line: int
    rule: str
    message: str
    context: str = ""  # enclosing scope, for line-stable baseline keys

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.message}"

    def baseline_key(self) -> str:
        """Line-number-free identity: unrelated edits that shift lines
        must not invalidate the checked-in baseline."""
        return f"{self.path}\t{self.rule}\t{self.context}\t{self.message}"


# ---------------------------------------------------------------------------
# inline suppression markers (see module docstring for the syntax)


def parse_suppressions(source: str, path: str) -> dict[int, set[str]]:
    """Map line number -> rules suppressed on that line.

    A marker suppresses its own line and the line below, so it can sit
    either trailing the offending statement or on its own line above.
    A missing/empty reason is itself an error (raised as ValueError so
    the driver reports it as a finding on the marker line).
    """
    out: dict[int, set[str]] = {}
    for lineno, comment in _comments(source):
        marker = comment.split("dnvm:", 1)
        if len(marker) != 2:
            continue
        body = marker[1].strip()
        if not body.startswith("ok(") or not body.endswith(")"):
            raise ValueError(
                f"{path}:{lineno} malformed suppression {comment!r}; "
                "expected '# dnvm: ok(RULE, reason)'")
        inner = body[len("ok("):-1]
        rule, _, reason = inner.partition(",")
        rule, reason = rule.strip(), reason.strip()
        if not rule.startswith("DNVM") or not reason:
            raise ValueError(
                f"{path}:{lineno} suppression needs a DNVM rule and a "
                f"non-empty reason: {comment!r}")
        for covered in (lineno, lineno + 1):
            out.setdefault(covered, set()).add(rule)
    return out


def _comments(source: str) -> Iterator[tuple[int, str]]:
    import io

    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.string
    except tokenize.TokenError:  # pragma: no cover - ast parsed already
        return


# ---------------------------------------------------------------------------
# baseline file


def load_baseline(path: str) -> set[str]:
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        return {line.rstrip("\n") for line in f
                if line.strip() and not line.startswith("#")}


def write_baseline(path: str, findings: Iterable[Finding]) -> int:
    keys = sorted({f.baseline_key() for f in findings})
    with open(path, "w") as f:
        f.write("# repro.analysis baseline — accepted findings, one per "
                "line (file<TAB>rule<TAB>scope<TAB>message).\n"
                "# Regenerate: python -m repro.analysis --write-baseline "
                "src/repro\n")
        for k in keys:
            f.write(k + "\n")
    return len(keys)


# ---------------------------------------------------------------------------
# module model


@dataclasses.dataclass
class ModuleInfo:
    path: str
    source: str
    tree: ast.Module
    suppressions: dict[int, set[str]]
    # module-level names that are reassigned or mutated after first
    # binding anywhere in the module — reading these from a memoized or
    # jitted body is key-blind / bakes trace-time state.
    varying_globals: set[str]
    # all module-level bindings (assignments, defs, imports)
    module_names: set[str]

    def scope_of(self, node: ast.AST) -> str:
        """Dotted enclosing def/class chain, e.g. 'Coalescer._run_group'."""
        parts = []
        cur = getattr(node, "_dnvm_parent", None)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = getattr(cur, "_dnvm_parent", None)
        return ".".join(reversed(parts)) or "<module>"


def link_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._dnvm_parent = parent  # type: ignore[attr-defined]


def load_module(path: str) -> ModuleInfo:
    with open(path) as f:
        source = f.read()
    tree = ast.parse(source, filename=path)
    link_parents(tree)
    return ModuleInfo(
        path=path,
        source=source,
        tree=tree,
        suppressions=parse_suppressions(source, path),
        varying_globals=_varying_globals(tree),
        module_names=_module_names(tree),
    )


def _module_names(tree: ast.Module) -> set[str]:
    names: set[str] = set()
    for node in tree.body:
        names |= _bound_names(node)
    return names


def _bound_names(node: ast.stmt) -> set[str]:
    out: set[str] = set()
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        out.add(node.name)
    elif isinstance(node, (ast.Import, ast.ImportFrom)):
        for a in node.names:
            out.add((a.asname or a.name).split(".")[0])
    elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = node.targets if isinstance(node, ast.Assign) else \
            [node.target]
        for t in targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    out.add(n.id)
    elif isinstance(node, (ast.If, ast.Try, ast.For, ast.While, ast.With)):
        for sub in ast.iter_child_nodes(node):
            if isinstance(sub, ast.stmt):
                out |= _bound_names(sub)
    return out


def _varying_globals(tree: ast.Module) -> set[str]:
    """Module-level names that are *not* assign-once constants.

    A name varies if it is (a) bound more than once at module level,
    (b) declared ``global`` and assigned inside any function, or
    (c) mutated in place anywhere — subscript/attribute store, augmented
    assignment, or a mutating method call (``x.append(...)``) on the
    bare name.  Dicts/tables assigned once and only ever read (the
    ``_ANCHORS``/``TABLE2`` registries) are constants, not findings.
    """
    bind_counts: dict[str, int] = {}
    varying: set[str] = set()

    for node in tree.body:
        for name in _bound_names(node):
            bind_counts[name] = bind_counts.get(name, 0) + 1
    # a module-level for loop rebinds its target every iteration but is
    # still "assign once" from the reader's perspective; keep simple:
    varying |= {n for n, c in bind_counts.items() if c > 1}

    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            fn = node
            while fn is not None and not isinstance(
                    fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn = getattr(fn, "_dnvm_parent", None)
            if fn is not None:
                varying |= set(node.names)
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else \
                [node.target]
            for t in targets:
                base = _store_base(t)
                if base is not None:
                    varying.add(base)
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr in _MUTATORS
                    and isinstance(f.value, ast.Name)):
                varying.add(f.value.id)
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                base = _store_base(t)
                if base is not None:
                    varying.add(base)
    return varying


def _store_base(target: ast.expr) -> str | None:
    """``x[k] = ...`` / ``x.attr = ...`` mutate the object bound to
    ``x``; a plain ``x = ...`` store does not count here (handled by the
    module-level bind count)."""
    if isinstance(target, (ast.Subscript, ast.Attribute)):
        inner = target.value
        while isinstance(inner, (ast.Subscript, ast.Attribute)):
            inner = inner.value
        if isinstance(inner, ast.Name):
            return inner.id
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            base = _store_base(elt)
            if base is not None:
                return base
    return None


# ---------------------------------------------------------------------------
# small AST helpers shared by the passes


def dotted(node: ast.expr) -> str | None:
    """'functools.lru_cache' for an Attribute/Name chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def decorator_name(dec: ast.expr) -> str | None:
    """Dotted name of a decorator, unwrapping a call: ``@lru_cache(...)``
    and ``@functools.partial(jax.jit, ...)`` -> 'lru_cache' /
    'functools.partial'."""
    if isinstance(dec, ast.Call):
        dec = dec.func
    return dotted(dec)


def func_params(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[str]:
    a = fn.args
    names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def local_bindings(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    """Names bound inside ``fn`` (params, assignments, imports, nested
    defs, comprehension targets, with/except/for targets)."""
    bound = set(func_params(fn))
    for node in ast.walk(fn):
        if node is fn:
            continue
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            bound.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for al in node.names:
                bound.add((al.asname or al.name).split(".")[0])
        elif isinstance(node, ast.Name) and isinstance(
                node.ctx, (ast.Store, ast.Del)):
            bound.add(node.id)
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
        elif isinstance(node, ast.arg):
            bound.add(node.arg)
    return bound


def loads_in(fn: ast.FunctionDef | ast.AsyncFunctionDef,
             skip_nested_defs: bool = False) -> Iterator[ast.Name]:
    """All Name loads in ``fn``'s body (optionally skipping nested
    function bodies)."""
    def visit(node: ast.AST) -> Iterator[ast.Name]:
        for child in ast.iter_child_nodes(node):
            if skip_nested_defs and isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
                continue
            if isinstance(child, ast.Name) and isinstance(child.ctx,
                                                          ast.Load):
                yield child
            yield from visit(child)
    yield from visit(fn)


def iter_functions(tree: ast.Module) -> Iterator[
        ast.FunctionDef | ast.AsyncFunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def walk_python_files(paths: list[str]) -> list[str]:
    """Expand files/directories into a sorted list of .py files."""
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in {"__pycache__", ".git"})
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
        else:
            raise FileNotFoundError(p)
    # normalise to repo-relative-ish forward-slash paths for stable keys
    return sorted({os.path.normpath(p).replace(os.sep, "/") for p in out})

"""CLI: ``python -m repro.analysis [paths...] [--strict] [--baseline F]
[--write-baseline] [--rules DNVM001,DNVM004]``.

Exit status: 0 when no unbaselined findings (or not ``--strict``); 1
when ``--strict`` and unbaselined findings remain; 2 on usage errors.
"""

from __future__ import annotations

import argparse
import sys
import time

from repro.analysis import common, driver


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="DeepNVM++ repo-specific static analysis "
                    "(DNVM001 memo keys, DNVM002 jit retrace, "
                    "DNVM003 units, DNVM004 locks)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files/directories to analyze "
                         "(default: src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any unbaselined finding remains")
    ap.add_argument("--baseline", default=common.BASELINE_DEFAULT,
                    metavar="FILE",
                    help="baseline file of accepted findings "
                         f"(default: {common.BASELINE_DEFAULT})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into --baseline")
    ap.add_argument("--rules", metavar="DNVM00X[,..]",
                    help="comma-separated rule subset (default: all)")
    args = ap.parse_args(argv)

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = sorted(set(rules) - set(driver.CHECKS))
        if unknown:
            ap.error(f"unknown rule(s): {', '.join(unknown)} "
                     f"(choose from {', '.join(driver.CHECKS)})")

    baseline = set() if args.no_baseline else \
        common.load_baseline(args.baseline)
    t0 = time.perf_counter()
    result = driver.run_paths(args.paths or ["src/repro"], rules=rules,
                              baseline=baseline)
    dt_ms = (time.perf_counter() - t0) * 1e3

    if args.write_baseline:
        n = common.write_baseline(args.baseline, result.findings)
        print(f"wrote {n} baseline entries to {args.baseline}")
        return 0

    for f in result.active:
        print(f.render())
    counts = ", ".join(f"{r}={n}" for r, n in sorted(
        result.counts.items()) if n)
    print(f"repro.analysis: {result.files} files, "
          f"{len(result.active)} finding(s)"
          f"{' (' + counts + ')' if counts else ''}, "
          f"{result.suppressed} suppressed, "
          f"{result.baselined} baselined, {dt_ms:.0f} ms",
          file=sys.stderr)
    if args.strict and result.active:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""repro.analysis — repo-specific static analysis for the DeepNVM++ tree.

Four AST passes encode the cross-layer invariants that previous PRs had
to recover from after the fact:

- **DNVM001 memo-key completeness** (`memo_keys`): a
  ``functools.lru_cache``/``cache``-decorated function must not read
  state that is outside its cache key — mutable module globals, closure
  variables, mutable default arguments — and a wrapper that forwards
  into a memoized callee must forward *every* parameter (the PR-4
  node-blind ``design_table`` bug class).
- **DNVM002 jit/retrace discipline** (`retrace`): inside ``jax.jit``
  kernels — no closure captures of mutable module state (baked at trace
  time), no Python branching on traced arguments that should be in
  ``static_argnames``, and no dtype-narrowing ``float32`` constructions
  in the ``enable_x64`` float64 modules (the PR-7 retrace/1-ulp hazard
  class).
- **DNVM003 unit consistency** (`units`): dimensional analysis over the
  ``_s/_w/_j/_f/_m/_ohm/_bytes`` suffix conventions and the registered
  ``tech``/``calibration``/``Periphery`` dataclass fields, propagated
  through the PPA arithmetic — seconds + joules is an error, ``_f *
  _ohm`` binding to an ``_s`` name is accepted.
- **DNVM004 lock discipline** (`locks`): attributes of a lock-owning
  class (or module) mutated outside a ``with self._lock/_cv`` block
  (the PR-8 service-counter class).

Findings print as ``file:line RULE message``.  Suppress a single site
inline with ``# dnvm: ok(RULE, reason)`` on the offending line or the
line above; accept legacy findings wholesale via the checked-in
baseline (``analysis-baseline.txt``, keyed without line numbers so
unrelated edits don't invalidate it).  CLI::

    python -m repro.analysis [paths...] [--strict] [--baseline FILE]
                             [--write-baseline] [--rules DNVM001,...]
"""

from __future__ import annotations

from repro.analysis.common import (  # noqa: F401
    BASELINE_DEFAULT,
    Finding,
    load_baseline,
    write_baseline,
)
from repro.analysis.driver import run_paths  # noqa: F401

RULES = ("DNVM001", "DNVM002", "DNVM003", "DNVM004")

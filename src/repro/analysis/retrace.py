"""DNVM002 — jax.jit retrace/trace-time discipline.

The engines trace their kernels a fixed number of times (PR 7 pins
``node_retraces == 0``) and run everything float64 under
``jax.experimental.enable_x64``.  Three trace-time hazards break those
contracts silently:

- **varying-global capture**: a jitted body reads a module-level name
  that is reassigned/mutated elsewhere — the value at *trace* time is
  baked into the compiled executable, so later mutations are ignored
  (or worse, keyed off ``id()`` and retraced unpredictably);
- **traced-argument branching**: a Python ``if``/``while``/``not`` on a
  jitted parameter that is not in ``static_argnames`` — either a
  ``TracerBoolConversionError`` at runtime or, if the arg is a weak
  Python scalar, one silent retrace per distinct value (the
  ``anchor_peri`` static flag in ``core/engine.py`` is the corrected
  form);
- **dtype narrowing**: ``float32``/``float16``/``bfloat16``
  constructions inside a jitted body of an ``enable_x64`` module — one
  narrowed intermediate is enough to lose the ≤1-ulp scalar parity the
  anchor tests pin.

Jitted regions are found through ``@jax.jit`` / ``@functools.partial(
jax.jit, ...)`` decorators and ``name = jax.jit(fn, ...)`` /
``jax.jit(shard_map(fn, ...))`` wrapping of a resolvable local
function.
"""

from __future__ import annotations

import ast
import dataclasses

from repro.analysis.common import (
    Finding,
    ModuleInfo,
    decorator_name,
    dotted,
    func_params,
    iter_functions,
    loads_in,
    local_bindings,
)

RULE = "DNVM002"

_JIT_NAMES = frozenset({"jax.jit", "jit"})
_PARTIAL_NAMES = frozenset({"functools.partial", "partial"})
_NARROW_DTYPES = frozenset({"float32", "float16", "bfloat16"})


@dataclasses.dataclass
class JitSite:
    fn: ast.FunctionDef | ast.AsyncFunctionDef
    static: set[str]


def check(mod: ModuleInfo) -> list[Finding]:
    sites = _jit_sites(mod)
    if not sites:
        return []
    x64_module = "enable_x64" in mod.source
    findings: list[Finding] = []
    for site in sites:
        findings += _check_captures(mod, site)
        findings += _check_static_branches(mod, site)
        if x64_module:
            findings += _check_dtypes(mod, site)
    return findings


# ---------------------------------------------------------------------------
# jit site discovery


def _jit_sites(mod: ModuleInfo) -> list[JitSite]:
    by_name = {fn.name: fn for fn in iter_functions(mod.tree)}
    sites: dict[ast.AST, JitSite] = {}

    for fn in iter_functions(mod.tree):
        static = _static_from_decorators(fn)
        if static is not None:
            sites[fn] = JitSite(fn, static)

    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call)
                and dotted(node.func) in _JIT_NAMES and node.args):
            continue
        target: ast.expr = node.args[0]
        # unwrap one transform layer: jax.jit(shard_map(body, ...))
        if isinstance(target, ast.Call) and target.args:
            target = target.args[0]
        if isinstance(target, ast.Name) and target.id in by_name:
            fn = by_name[target.id]
            static = _static_names(node, fn)
            if fn in sites:
                sites[fn].static |= static
            else:
                sites[fn] = JitSite(fn, static)
    return list(sites.values())


def _static_from_decorators(
        fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str] | None:
    for dec in fn.decorator_list:
        name = decorator_name(dec)
        if name in _JIT_NAMES:
            return _static_names(dec, fn) if isinstance(dec, ast.Call) \
                else set()
        if (name in _PARTIAL_NAMES and isinstance(dec, ast.Call)
                and dec.args and dotted(dec.args[0]) in _JIT_NAMES):
            return _static_names(dec, fn)
    return None


def _static_names(call: ast.Call,
                  fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    params = func_params(fn)
    out: set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            out |= set(_str_values(kw.value))
        elif kw.arg == "static_argnums":
            for i in _int_values(kw.value):
                if 0 <= i < len(params):
                    out.add(params[i])
    return out


def _str_values(node: ast.expr) -> list[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, str)]
    return []


def _int_values(node: ast.expr) -> list[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [e.value for e in node.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)]
    return []


# ---------------------------------------------------------------------------
# checks


def _check_captures(mod: ModuleInfo, site: JitSite) -> list[Finding]:
    out = []
    local = local_bindings(site.fn)
    seen: set[str] = set()
    for name in loads_in(site.fn):
        if name.id in local or name.id in seen:
            continue
        if name.id in mod.varying_globals:
            seen.add(name.id)
            out.append(Finding(
                mod.path, name.lineno, RULE,
                f"jitted '{site.fn.name}' captures mutable module state "
                f"'{name.id}' — baked in at trace time",
                mod.scope_of(name)))
    return out


def _check_static_branches(mod: ModuleInfo, site: JitSite) -> list[Finding]:
    traced = set(func_params(site.fn)) - site.static - {"self", "cls"}
    out = []
    flagged: set[str] = set()
    for node in ast.walk(site.fn):
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
        elif isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
            test = node.operand
        elif isinstance(node, ast.IfExp):
            test = node.test
        else:
            continue
        for used in _bare_param_uses(test, traced):
            if used.id in flagged:
                continue
            flagged.add(used.id)
            out.append(Finding(
                mod.path, used.lineno, RULE,
                f"jitted '{site.fn.name}' branches on traced argument "
                f"'{used.id}' — add it to static_argnames",
                mod.scope_of(used)))
    return out


def _bare_param_uses(test: ast.expr, params: set[str]) -> list[ast.Name]:
    """Bare Name uses of a traced param in a branch test.  Attribute
    access (``x.ndim``) and ``len(x)``/``isinstance(x, ...)`` are
    shape/type queries — static under tracing — and stay silent."""
    out = []
    for node in ast.walk(test):
        if not (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                and node.id in params):
            continue
        parent = getattr(node, "_dnvm_parent", None)
        if isinstance(parent, ast.Attribute) and parent.value is node:
            continue
        if (isinstance(parent, ast.Call) and node in parent.args
                and dotted(parent.func) in ("len", "isinstance", "type")):
            continue
        out.append(node)
    return out


def _check_dtypes(mod: ModuleInfo, site: JitSite) -> list[Finding]:
    out = []
    for node in ast.walk(site.fn):
        token = _narrow_token(node)
        if token is not None:
            out.append(Finding(
                mod.path, node.lineno, RULE,
                f"jitted '{site.fn.name}' uses {token} — narrows the "
                "enable_x64 float64 contract",
                mod.scope_of(node)))
    return out


def _narrow_token(node: ast.AST) -> str | None:
    if isinstance(node, ast.Attribute) and node.attr in _NARROW_DTYPES:
        base = dotted(node.value)
        if base in ("jnp", "np", "jax.numpy", "numpy", "jax"):
            return f"{base}.{node.attr}"
    if (isinstance(node, ast.Constant) and isinstance(node.value, str)
            and node.value in _NARROW_DTYPES):
        return f"dtype string '{node.value}'"
    return None

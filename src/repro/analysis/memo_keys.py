"""DNVM001 — memo-key completeness.

A ``functools.lru_cache``/``cache``-decorated function's cache key is
exactly its argument tuple.  Anything else its body reads — mutable
module globals, closure variables, mutable defaults — is invisible to
the key, so a change in that state silently serves stale results.  The
canonical incident is PR 4's node-blind ``design_table``: a thin public
wrapper gained a ``node`` parameter but kept forwarding into the
memoized worker without it, so every node returned the 16 nm tables.

Checks:

- **varying-global read**: the body loads a module-level name that is
  reassigned or mutated somewhere in the module (assign-once registry
  dicts and imported modules are constants and stay silent);
- **closure read**: the body loads a name bound in an enclosing
  function — per-call state baked into a cross-call cache;
- **mutable default**: a list/dict/set (display or constructor call)
  default argument survives across calls outside the key;
- **key-blind wrapper**: a function that calls a memoized sibling but
  never reads one of its own parameters — the parameter cannot have
  reached the cache key.
"""

from __future__ import annotations

import ast

from repro.analysis.common import (
    Finding,
    ModuleInfo,
    decorator_name,
    func_params,
    iter_functions,
    loads_in,
    local_bindings,
)

RULE = "DNVM001"

_MEMO_DECORATORS = frozenset({
    "functools.cache", "functools.lru_cache", "cache", "lru_cache",
})
_PROPERTY_MEMO_DECORATORS = frozenset({
    "functools.cached_property", "cached_property",
})
_MUTABLE_DEFAULT_CALLS = frozenset({"list", "dict", "set", "defaultdict",
                                    "collections.defaultdict"})


def memo_kind(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> str | None:
    for dec in fn.decorator_list:
        name = decorator_name(dec)
        if name in _MEMO_DECORATORS:
            return "cache"
        if name in _PROPERTY_MEMO_DECORATORS:
            return "cached_property"
    return None


def check(mod: ModuleInfo) -> list[Finding]:
    findings: list[Finding] = []
    memoized: dict[str, ast.FunctionDef | ast.AsyncFunctionDef] = {}
    for fn in iter_functions(mod.tree):
        kind = memo_kind(fn)
        if kind is None:
            continue
        if kind == "cache":
            memoized[fn.name] = fn
        findings += _check_body_reads(mod, fn)
        if kind == "cache":
            findings += _check_defaults(mod, fn)
    for fn in iter_functions(mod.tree):
        if fn.name not in memoized:
            findings += _check_wrapper(mod, fn, memoized)
    return findings


def _check_body_reads(mod: ModuleInfo,
                      fn: ast.FunctionDef | ast.AsyncFunctionDef,
                      ) -> list[Finding]:
    out = []
    local = local_bindings(fn)
    enclosing = _enclosing_locals(fn)
    seen: set[str] = set()
    for name in loads_in(fn):
        if name.id in local or name.id in seen:
            continue
        if name.id in enclosing:
            seen.add(name.id)
            out.append(Finding(
                mod.path, name.lineno, RULE,
                f"memoized '{fn.name}' reads closure variable "
                f"'{name.id}' — per-call state outside the cache key",
                mod.scope_of(name)))
        elif name.id in mod.varying_globals:
            seen.add(name.id)
            out.append(Finding(
                mod.path, name.lineno, RULE,
                f"memoized '{fn.name}' reads mutable module state "
                f"'{name.id}' — not part of the cache key",
                mod.scope_of(name)))
    return out


def _check_defaults(mod: ModuleInfo,
                    fn: ast.FunctionDef | ast.AsyncFunctionDef,
                    ) -> list[Finding]:
    out = []
    a = fn.args
    pairs = list(zip([p.arg for p in (*a.posonlyargs, *a.args)][
        len(a.posonlyargs) + len(a.args) - len(a.defaults):], a.defaults))
    pairs += [(p.arg, d) for p, d in zip(a.kwonlyargs, a.kw_defaults)
              if d is not None]
    for pname, default in pairs:
        bad = isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
            isinstance(default, ast.Call)
            and _callname(default) in _MUTABLE_DEFAULT_CALLS)
        if bad:
            out.append(Finding(
                mod.path, default.lineno, RULE,
                f"memoized '{fn.name}' has mutable default for "
                f"'{pname}' — shared across calls outside the cache key",
                mod.scope_of(default)))
    return out


def _check_wrapper(mod: ModuleInfo,
                   fn: ast.FunctionDef | ast.AsyncFunctionDef,
                   memoized: dict[str, ast.FunctionDef],
                   ) -> list[Finding]:
    """A wrapper forwarding into a memoized sibling must read every one
    of its parameters — an unread parameter cannot be in the key."""
    callees = {n.func.id for n in ast.walk(fn)
               if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
               and n.func.id in memoized}
    if not callees:
        return []
    read = {n.id for n in loads_in(fn)}
    out = []
    for pname in func_params(fn):
        if pname.startswith("_") or pname in ("self", "cls"):
            continue
        if pname not in read:
            out.append(Finding(
                mod.path, fn.lineno, RULE,
                f"'{fn.name}' parameter '{pname}' is never read but it "
                f"calls memoized {sorted(callees)} — key-blind wrapper "
                "(the PR-4 design_table bug class)",
                mod.scope_of(fn.body[0]) if fn.body else fn.name))
    return out


def _enclosing_locals(fn: ast.AST) -> set[str]:
    names: set[str] = set()
    cur = getattr(fn, "_dnvm_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names |= local_bindings(cur)
        cur = getattr(cur, "_dnvm_parent", None)
    return names


def _callname(call: ast.Call) -> str | None:
    from repro.analysis.common import dotted
    return dotted(call.func)

"""DeepSeek-V3 671B [arXiv:2412.19437; hf]: MLA, 1 shared + 256 routed
top-8 experts, MTP, 3 leading dense layers."""
from repro.configs.base import ArchConfig, MLASpec, MoESpec


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=2048, vocab=129280, mtp=True,
        mla=MLASpec(q_lora_rank=1536, kv_lora_rank=512,
                    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoESpec(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                    first_dense_layers=3, dense_d_ff=18432),
    )


def reduced_config() -> ArchConfig:
    # 2 layers (1 dense + 1 MoE) and 4 experts: the smallest shape that
    # still exercises the MLA, routed+shared expert, and MTP paths — eager
    # smoke-test cost scales with op count, not parameter size
    return ArchConfig(
        name="deepseek-v3-671b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab=256, mtp=True,
        mla=MLASpec(q_lora_rank=32, kv_lora_rank=16,
                    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        moe=MoESpec(n_experts=4, top_k=2, d_expert=64, n_shared=1,
                    first_dense_layers=1, dense_d_ff=128, group_size=32,
                    capacity_factor=8.0),
    )

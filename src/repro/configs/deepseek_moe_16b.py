"""DeepSeek-MoE 16B [arXiv:2401.06066; hf]: fine-grained MoE, 2 shared +
64 routed top-6 experts, first layer dense."""
from repro.configs.base import ArchConfig, MoESpec


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1408, vocab=102400,
        moe=MoESpec(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                    first_dense_layers=1, dense_d_ff=10944),
    )


def reduced_config() -> ArchConfig:
    # 2 layers (1 dense + 1 MoE) and 4 experts: keeps the fine-grained
    # routed+shared expert path at the minimum eager op count
    return ArchConfig(
        name="deepseek-moe-16b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=256,
        moe=MoESpec(n_experts=4, top_k=2, d_expert=96, n_shared=1,
                    first_dense_layers=1, dense_d_ff=192, group_size=32,
                    capacity_factor=8.0),
    )

"""Chameleon 34B [arXiv:2405.09818]: early-fusion VLM; VQ image tokens are
regular vocab entries (stub tokenizer), qk-norm backbone."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="chameleon-34b", family="vlm",
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22016, vocab=65536, qk_norm=True,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="chameleon-34b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, qk_norm=True,
    )

"""Qwen3 14B [hf:Qwen/Qwen3-8B family; hf]: qk-norm, GQA kv=8."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=17408, vocab=151936, qk_norm=True, rope_theta=1e6,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-14b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, qk_norm=True,
    )

"""Config dataclasses shared by all architectures + the assigned shapes."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    first_dense_layers: int = 0   # leading dense layers (DeepSeek style)
    dense_d_ff: int = 0           # d_ff of those dense layers
    group_size: int = 512
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLASpec:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    state_dim: int = 16
    conv_k: int = 4
    # hybrid (Hymba): indices of global-attention layers; others use SWA
    global_attn_layers: tuple[int, ...] = ()
    sliding_window: int = 1024


@dataclasses.dataclass(frozen=True)
class EncDecSpec:
    n_encoder_layers: int
    n_frames: int = 1500          # stub frontend: precomputed embeddings


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    activation: str = "silu"      # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10000.0
    tied_embeddings: bool = False
    embed_scale_by_dim: bool = False   # Gemma-style sqrt(d) embed scale
    residual_scale: float = 1.0        # MiniCPM depth scaling
    logit_cap: float = 0.0
    mtp: bool = False                  # DeepSeek-V3 multi-token prediction
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    ssm: Optional[SSMSpec] = None
    rwkv: bool = False
    encdec: Optional[EncDecSpec] = None

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / linear attention)."""
        return self.rwkv or self.ssm is not None

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, l = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tied_embeddings else 2)
        if self.rwkv:
            block = 6 * d * d + 2 * d * self.d_ff
        elif self.mla is not None:
            m = self.mla
            attn = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                m.qk_nope_dim + m.qk_rope_dim)
            attn += d * (m.kv_lora_rank + m.qk_rope_dim)
            attn += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            attn += self.n_heads * m.v_head_dim * d
            block = attn
        else:
            attn = d * self.n_heads * self.head_dim * 2 \
                + d * self.n_kv_heads * self.head_dim * 2
            block = attn
        if self.moe is not None:
            ffn = 3 * d * self.moe.d_expert * (self.moe.n_experts
                                               + self.moe.n_shared)
        elif not self.rwkv:
            ffn = 3 * d * self.d_ff
        else:
            ffn = 0
        if self.ssm is not None:
            ffn += 3 * d * d  # in/out projections of the SSM branch
        return emb + l * (block + ffn)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

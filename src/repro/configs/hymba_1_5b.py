"""Hymba 1.5B [arXiv:2411.13676; hf]: parallel attention + Mamba heads,
global attention in 3 layers (first/middle/last), SWA elsewhere."""
from repro.configs.base import ArchConfig, SSMSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, vocab=32001,
        ssm=SSMSpec(state_dim=16, global_attn_layers=(0, 15, 31),
                    sliding_window=1024),
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b-smoke", family="hybrid",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        ssm=SSMSpec(state_dim=4, global_attn_layers=(0, 2),
                    sliding_window=16),
    )

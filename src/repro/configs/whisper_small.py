"""Whisper small [arXiv:2212.04356]: encoder-decoder; conv frontend is a
stub (input_specs provides precomputed 1500-frame embeddings)."""
from repro.configs.base import ArchConfig, EncDecSpec


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab=51865, activation="gelu",
        encdec=EncDecSpec(n_encoder_layers=12, n_frames=1500),
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="whisper-small-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, activation="gelu",
        encdec=EncDecSpec(n_encoder_layers=2, n_frames=32),
    )

"""MiniCPM 2B [arXiv:2404.06395; hf]: llama-like, WSD schedule (wired in
optim/schedules.py), depth-scaled residuals, tied embeddings."""
import math

from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
        d_ff=5760, vocab=122753, tied_embeddings=True,
        residual_scale=1.4 / math.sqrt(40),
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="minicpm-2b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, tied_embeddings=True,
        residual_scale=1.4 / math.sqrt(2),
    )

"""TinyLlama 1.1B [arXiv:2401.02385; hf]: llama2-arch small, GQA kv=4."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-1.1b", family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
        d_ff=5632, vocab=32000,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="tinyllama-1.1b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
    )

"""RWKV6 (Finch) 3B [arXiv:2404.05892; hf]: attention-free, data-dependent
decay WKV recurrence."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
        d_ff=8960, vocab=65536, rwkv=True,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-3b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256, rwkv=True,
    )

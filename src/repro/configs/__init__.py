"""Architecture configs: one module per assigned architecture.

`get(name)` returns the full published config; `get(name, reduced=True)`
returns the smoke-test reduction of the same family (few layers, narrow,
tiny vocab) used by per-arch CPU smoke tests.
"""

from __future__ import annotations

from repro.configs.base import ArchConfig, SHAPES, ShapeSpec  # noqa: F401 — re-exported config vocabulary

_ARCH_MODULES = (
    "deepseek_moe_16b",
    "deepseek_v3_671b",
    "tinyllama_1_1b",
    "qwen3_14b",
    "gemma_7b",
    "minicpm_2b",
    "hymba_1_5b",
    "whisper_small",
    "rwkv6_3b",
    "chameleon_34b",
)

ARCH_IDS = tuple(m.replace("_", "-").replace("-1-1b", "-1.1b")
                 .replace("-1-5b", "-1.5b") for m in _ARCH_MODULES)


def _module_for(name: str):
    import importlib
    mod = name.replace("-", "_").replace("1.1b", "1_1b").replace("1.5b", "1_5b")
    return importlib.import_module(f"repro.configs.{mod}")


def get(name: str, reduced: bool = False) -> ArchConfig:
    m = _module_for(name)
    return m.reduced_config() if reduced else m.config()


def all_archs() -> tuple[str, ...]:
    return ARCH_IDS

"""Gemma 7B [arXiv:2403.08295; hf]: GeGLU, head_dim=256, MHA (kv=16),
sqrt(d) embedding scale, tied embeddings."""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b", family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256000, activation="gelu",
        tied_embeddings=True, embed_scale_by_dim=True, logit_cap=30.0,
    )


def reduced_config() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=256, activation="gelu",
        tied_embeddings=True, embed_scale_by_dim=True, logit_cap=30.0,
    )

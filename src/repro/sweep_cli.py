"""Sweep-as-a-service: the CLI / service facade over symbolic SweepSpecs.

    python -m repro.sweep run spec.json --csv out.csv
    python -m repro.sweep show spec.json
    python -m repro.sweep serve < requests.jsonl

``run`` lowers one JSON spec document (core/sweep.py, schema
``deepnvm.sweepspec/2``) through the registries and evaluates it — exactly
one circuit-engine call plus one workload-fold call — then writes the
long-format rows as full-precision CSV (floats repr-round-trip, so a
JSON-defined sweep reproduces the Python pipeline bit-for-bit).  With
``--shard``/``--design-chunk`` (plus ``--devices``/``--by-width``) the
spec instead takes the chunked/sharded lowering (``core.sweep.ShardPlan``)
and streams partial results through the order-invariant merge — the path
for mega-specs too large for one fold.  ``mega`` builds and runs the full
DTCO cross product (``repro.scenarios.mega_spec``, 1e5+ cells) through
that path.  ``show`` resolves without evaluating (spec linting).
``serve`` is the long-lived mode: it answers JSONL sweep requests from
stdin on stdout, one response line per request, with every memoized layer
(scenario statistics, design tables, Algorithm-1 tunings, fold tables,
sweep results) staying warm across requests — repeated or overlapping
specs cost one evaluation.

A serve request is either a bare spec document or an envelope::

    {"spec": {...}, "want": ["rows", "summary", "pareto", "plateaus"],
     "include_dram": false,
     "shard": {"scenario_chunk": 8, "design_chunk": 32,
               "devices": null, "by_width": true}}

The response is one JSON object: ``{"ok": true, "name": ..., "axes":
{...}, "cells": ..., "elapsed_ms": ..., <one key per requested view>}`` —
``cells`` and ``elapsed_ms`` report per-request evaluated-cell count and
wall-clock (the observability hook the sharded path and the concurrent
service rely on) — or ``{"ok": false, "error": ...}`` on a bad request
(the process keeps serving).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from collections.abc import Mapping

from repro.core import report
from repro.core.sweep import ShardPlan, SymbolicSweepSpec, n_cells

WANTS = ("rows", "summary", "pareto", "plateaus")
SHARD_KEYS = ("scenario_chunk", "design_chunk", "devices", "by_width")


def _load(path: str) -> SymbolicSweepSpec:
    if path == "-":
        return SymbolicSweepSpec.from_json(sys.stdin.read())
    return SymbolicSweepSpec.load(path)


def _axes(spec) -> dict:
    return {"platforms": len(spec.platforms),
            "scenarios": len(spec.scenarios),
            "designs": len(spec.designs)}


def _plan_of(args: argparse.Namespace) -> ShardPlan | None:
    if not (args.shard or args.design_chunk or args.devices
            or args.by_width):
        return None
    return ShardPlan(scenario_chunk=args.shard,
                     design_chunk=args.design_chunk,
                     devices=args.devices, by_width=args.by_width)


def _progress(i: int, total: int, part) -> None:
    print(f"\r  shard {i}/{total} ({part.spec.name})",
          end="" if i < total else "\n", file=sys.stderr, flush=True)


def _add_shard_flags(p: argparse.ArgumentParser) -> None:
    p.add_argument("--shard", type=int, metavar="N",
                   help="sharded lowering: chunk the scenario axis by N")
    p.add_argument("--design-chunk", type=int, metavar="N",
                   help="chunk the design axis by N")
    p.add_argument("--devices", type=int, metavar="N",
                   help="shard_map chunk groups over N devices (CPU: set "
                        "XLA_FLAGS=--xla_force_host_platform_device_count)")
    p.add_argument("--by-width", action="store_true",
                   help="order scenarios by stream count before chunking "
                        "(minimizes padded-SoA area per chunk)")


def _run_spec(spec, plan: ShardPlan | None):
    from repro.core import sweep as sweep_mod
    if plan is None:
        return sweep_mod.run(spec)
    return sweep_mod.run_sharded(spec, plan, progress=_progress)


def cmd_run(args: argparse.Namespace) -> None:
    sym = _load(args.spec)
    result = _run_spec(sym.resolve(), _plan_of(args))
    rows = result.rows(include_norm=not args.no_norm,
                       include_dram=args.include_dram)
    # status lines go to stderr: stdout carries only data (the rows CSV
    # when --csv is omitted, the --summary JSON), so redirection is safe
    if args.csv:
        report.write_csv(args.csv, rows, fmt=report.fmt_exact)
        axes = _axes(result.spec)
        print(f"{sym.name}: {len(rows)} rows "
              f"({axes['platforms']} platforms x {axes['scenarios']} "
              f"scenarios x {axes['designs']} designs) -> {args.csv}",
              file=sys.stderr)
    else:
        sys.stdout.write(report.csv_str(rows, fmt=report.fmt_exact))
    if args.pareto:
        report.write_csv(args.pareto, result.pareto_front(
            include_dram=args.include_dram), fmt=report.fmt_exact)
        print(f"pareto front -> {args.pareto}", file=sys.stderr)
    if args.plateaus:
        report.write_csv(args.plateaus, result.capacity_plateaus(),
                         fmt=report.fmt_exact)
        print(f"capacity plateaus -> {args.plateaus}", file=sys.stderr)
    if args.summary:
        print(json.dumps(result.summary(), indent=2))


def cmd_mega(args: argparse.Namespace) -> None:
    """Build and run the full DTCO cross product through the sharded
    lowering (default plan: 8-scenario x 32-design chunks, width-sorted —
    a few thousand cells per chunk, bounded peak memory)."""
    from repro import scenarios
    from repro.core.sweep import n_cells as cells_of
    spec = scenarios.mega_spec(quick=args.quick)
    # mega is always sharded: unset knobs take chunked defaults (8 x 32,
    # width-sorted — a few thousand cells per chunk, bounded peak memory)
    plan = ShardPlan(scenario_chunk=args.shard or 8,
                     design_chunk=args.design_chunk or 32,
                     devices=args.devices, by_width=True)
    print(f"{spec.name}: {cells_of(spec)} cells "
          f"({len(spec.platforms)} platforms x {len(spec.scenarios)} "
          f"scenarios x {len(spec.designs)} designs), plan {plan}",
          file=sys.stderr)
    t0 = time.perf_counter()
    result = _run_spec(spec, plan)
    dt = time.perf_counter() - t0
    print(f"evaluated in {dt:.1f}s "
          f"({cells_of(spec) / dt:,.0f} cells/s)", file=sys.stderr)
    if args.csv:
        report.write_csv(args.csv, result.rows(), fmt=report.fmt_exact)
        print(f"rows -> {args.csv}", file=sys.stderr)
    if args.summary or not args.csv:
        print(json.dumps(result.summary(), indent=2))


def cmd_show(args: argparse.Namespace) -> None:
    sym = _load(args.spec)
    spec = sym.resolve()
    axes = _axes(spec)
    print(f"{spec.name}: {axes['platforms']} platforms x "
          f"{axes['scenarios']} scenarios x {axes['designs']} designs, "
          f"baseline {spec.baseline_mem!r}")
    print("platforms:", ", ".join(p.name for p in spec.platforms))
    print("scenarios:", ", ".join(sym.scenarios))
    print("designs:")
    for p in spec.designs:
        print(f"  {p.mem}@{p.capacity_mb:g}MB @{p.node.name} "
              f"(group {p.group!r})")


def answer(request: Mapping | str) -> dict:
    """One serve-mode request -> one response document."""
    try:
        req = json.loads(request) if isinstance(request, str) else request
        envelope = isinstance(req, Mapping) and "spec" in req
        doc = req["spec"] if envelope else req
        want = tuple(req.get("want", ("summary",))) if envelope \
            else ("summary",)
        unknown = set(want) - set(WANTS)
        if unknown:
            raise ValueError(f"unknown want items {sorted(unknown)}; "
                             f"available: {list(WANTS)}")
        include_dram = bool(req.get("include_dram", False)) if envelope \
            else False
        plan = None
        if envelope and req.get("shard") is not None:
            shard = dict(req["shard"])
            unknown = set(shard) - set(SHARD_KEYS)
            if unknown:
                raise ValueError(f"unknown shard keys {sorted(unknown)}; "
                                 f"available: {list(SHARD_KEYS)}")
            plan = ShardPlan(**shard)
        sym = SymbolicSweepSpec.from_json(doc)
        spec = sym.resolve()
        t0 = time.perf_counter()
        result = spec.run(plan)
        resp: dict = {"ok": True, "name": sym.name,
                      "axes": _axes(result.spec),
                      "cells": n_cells(result.spec),
                      "elapsed_ms": (time.perf_counter() - t0) * 1e3}
        if "rows" in want:
            resp["rows"] = result.rows(include_dram=include_dram)
        if "summary" in want:
            resp["summary"] = result.summary()
        if "pareto" in want:
            resp["pareto"] = result.pareto_front(include_dram=include_dram)
        if "plateaus" in want:
            resp["plateaus"] = result.capacity_plateaus()
        return resp
    except Exception as e:  # noqa: BLE001 — the server loop must survive
        return {"ok": False, "error": f"{type(e).__name__}: {e}"}


def serve(in_stream=None, out_stream=None) -> int:
    """Long-lived JSONL loop: one request per line in, one response line
    out.  Engine caches persist for the life of the process, so a warm
    server answers repeated specs without re-evaluating anything."""
    in_stream = in_stream if in_stream is not None else sys.stdin
    out_stream = out_stream if out_stream is not None else sys.stdout
    served = 0
    for line in in_stream:
        if not line.strip():
            continue
        out_stream.write(json.dumps(answer(line)) + "\n")
        out_stream.flush()
        served += 1
    return served


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep",
        description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    run_p = sub.add_parser("run", help="evaluate a spec JSON document")
    run_p.add_argument("spec", help="path to spec.json ('-' for stdin)")
    run_p.add_argument("--csv", metavar="PATH",
                       help="write rows CSV here (default: stdout)")
    run_p.add_argument("--pareto", metavar="PATH",
                       help="also write the per-scenario Pareto front")
    run_p.add_argument("--plateaus", metavar="PATH",
                       help="also write capacity-plateau rows")
    run_p.add_argument("--summary", action="store_true",
                       help="print the aggregate summary as JSON")
    run_p.add_argument("--no-norm", action="store_true",
                       help="omit the normalized (*_x) columns")
    run_p.add_argument("--include-dram", action="store_true",
                       help="include DRAM terms in energy/EDP columns")
    _add_shard_flags(run_p)
    run_p.set_defaults(func=cmd_run)

    mega_p = sub.add_parser(
        "mega", help="run the full 1e5-cell DTCO cross product (sharded)")
    mega_p.add_argument("--quick", action="store_true",
                        help="CI-smoke size (a few hundred cells)")
    mega_p.add_argument("--csv", metavar="PATH",
                        help="write rows CSV here")
    mega_p.add_argument("--summary", action="store_true",
                        help="print the aggregate summary as JSON")
    _add_shard_flags(mega_p)
    mega_p.set_defaults(func=cmd_mega)

    show_p = sub.add_parser("show", help="resolve a spec without running")
    show_p.add_argument("spec")
    show_p.set_defaults(func=cmd_show)

    serve_p = sub.add_parser(
        "serve", help="answer JSONL sweep requests from stdin (warm caches)")
    serve_p.set_defaults(func=lambda args: serve())

    args = ap.parse_args(argv)
    args.func(args)


if __name__ == "__main__":
    main()

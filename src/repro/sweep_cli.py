"""Sweep-as-a-service: the CLI / service facade over symbolic SweepSpecs.

    python -m repro.sweep run spec.json --csv out.csv
    python -m repro.sweep show spec.json
    python -m repro.sweep invert specs/inverse_isocap.json
    python -m repro.sweep invert spec.json --objective edp --iso-area
    python -m repro.sweep serve < requests.jsonl
    python -m repro.sweep serve --http 127.0.0.1:8731 \
        --warmup-spec specs/isocap.json --stats-on-exit

``run`` lowers one JSON spec document (core/sweep.py, schema
``deepnvm.sweepspec/2``) through the registries and evaluates it — exactly
one circuit-engine call plus one workload-fold call — then writes the
long-format rows as full-precision CSV (floats repr-round-trip, so a
JSON-defined sweep reproduces the Python pipeline bit-for-bit).  With
``--shard``/``--design-chunk`` (plus ``--devices``/``--by-width``) the
spec instead takes the chunked/sharded lowering (``core.sweep.ShardPlan``)
and streams partial results through the order-invariant merge — the path
for mega-specs too large for one fold.  ``mega`` builds and runs the full
DTCO cross product (``repro.scenarios.mega_spec``, 1e5+ cells) through
that path.  ``show`` resolves without evaluating (spec linting).

``invert`` runs the gradient-based inverse-design solver
(:mod:`repro.inverse`) over a spec's corner grid: it accepts either a
``deepnvm.inverse/1`` problem document or a bare sweepspec plus flags
(``--objective edp --iso-area`` is the paper-style "minimize EDP at the
grid's own max area" question), prints the converged-design summary to
stderr, and emits the auditable result document (leaves, standard-path
re-evaluation, parity, gain vs the grid argmin) as JSON.

``serve`` is the long-lived mode, backed by the concurrent
:class:`repro.sweep.service.SweepService` (see that module for the full
story: transports, request coalescing, result cache, warmup).  With no
transport flag it keeps the historical stdin JSONL contract — one request
per line in, one response line out; ``--http HOST:PORT`` and/or
``--unix PATH`` start threaded socket transports over the same handler
(``--stdin`` adds the stdin loop alongside them).  ``--warmup`` /
``--warmup-spec PATH`` / ``--compile-cache DIR`` pre-trace kernels before
the first request; ``--window-ms`` / ``--max-batch`` / ``--no-coalesce``
tune the coalescing window; ``--stats-on-exit`` prints the stats document
to stderr on shutdown.  SIGTERM/SIGINT shut down gracefully: in-flight
requests (including any in the coalescing window) are answered first.

A serve request is either a bare spec document, an envelope, or an op::

    {"spec": {...}, "want": ["rows", "summary", "pareto", "plateaus"],
     "include_dram": false,
     "shard": {"scenario_chunk": 8, "design_chunk": 32,
               "devices": null, "by_width": true}}
    {"op": "stats"}

The response is one JSON object: ``{"ok": true, "name": ..., "axes":
{...}, "cells": ..., "elapsed_ms": ..., "source": "evaluated" |
"coalesced" | "cache" | "sharded", <one key per requested view>}`` — or
``{"ok": false, "error": ...}`` on a bad request (the process keeps
serving).
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from collections.abc import Mapping

from repro.core import report
from repro.core.sweep import ShardPlan, SymbolicSweepSpec
from repro.sweep.service import (  # noqa: F401 — re-exported vocabulary
    SHARD_KEYS,
    WANTS,
    SweepService,
)
from repro.sweep import service as service_mod


def _load(path: str) -> SymbolicSweepSpec:
    if path == "-":
        return SymbolicSweepSpec.from_json(sys.stdin.read())
    return SymbolicSweepSpec.load(path)


def _axes(spec) -> dict:
    return {"platforms": len(spec.platforms),
            "scenarios": len(spec.scenarios),
            "designs": len(spec.designs)}


def _plan_of(args: argparse.Namespace) -> ShardPlan | None:
    if not (args.shard or args.design_chunk or args.devices
            or args.by_width):
        return None
    return ShardPlan(scenario_chunk=args.shard,
                     design_chunk=args.design_chunk,
                     devices=args.devices, by_width=args.by_width)


def _progress(i: int, total: int, part) -> None:
    print(f"\r  shard {i}/{total} ({part.spec.name})",
          end="" if i < total else "\n", file=sys.stderr, flush=True)


def _add_shard_flags(p: argparse.ArgumentParser) -> None:
    p.add_argument("--shard", type=int, metavar="N",
                   help="sharded lowering: chunk the scenario axis by N")
    p.add_argument("--design-chunk", type=int, metavar="N",
                   help="chunk the design axis by N")
    p.add_argument("--devices", type=int, metavar="N",
                   help="shard_map chunk groups over N devices (CPU: set "
                        "XLA_FLAGS=--xla_force_host_platform_device_count)")
    p.add_argument("--by-width", action="store_true",
                   help="order scenarios by stream count before chunking "
                        "(minimizes padded-SoA area per chunk)")


def _run_spec(spec, plan: ShardPlan | None):
    from repro.core import sweep as sweep_mod
    if plan is None:
        return sweep_mod.run(spec)
    return sweep_mod.run_sharded(spec, plan, progress=_progress)


def cmd_run(args: argparse.Namespace) -> None:
    sym = _load(args.spec)
    result = _run_spec(sym.resolve(), _plan_of(args))
    rows = result.rows(include_norm=not args.no_norm,
                       include_dram=args.include_dram)
    # status lines go to stderr: stdout carries only data (the rows CSV
    # when --csv is omitted, the --summary JSON), so redirection is safe
    if args.csv:
        report.write_csv(args.csv, rows, fmt=report.fmt_exact)
        axes = _axes(result.spec)
        print(f"{sym.name}: {len(rows)} rows "
              f"({axes['platforms']} platforms x {axes['scenarios']} "
              f"scenarios x {axes['designs']} designs) -> {args.csv}",
              file=sys.stderr)
    else:
        sys.stdout.write(report.csv_str(rows, fmt=report.fmt_exact))
    if args.pareto:
        report.write_csv(args.pareto, result.pareto_front(
            include_dram=args.include_dram), fmt=report.fmt_exact)
        print(f"pareto front -> {args.pareto}", file=sys.stderr)
    if args.plateaus:
        report.write_csv(args.plateaus, result.capacity_plateaus(),
                         fmt=report.fmt_exact)
        print(f"capacity plateaus -> {args.plateaus}", file=sys.stderr)
    if args.summary:
        print(json.dumps(result.summary(), indent=2))


def cmd_mega(args: argparse.Namespace) -> None:
    """Build and run the full DTCO cross product through the sharded
    lowering (default plan: 8-scenario x 32-design chunks, width-sorted —
    a few thousand cells per chunk, bounded peak memory)."""
    from repro import scenarios
    from repro.core.sweep import n_cells as cells_of
    spec = scenarios.mega_spec(quick=args.quick)
    # mega is always sharded: unset knobs take chunked defaults (8 x 32,
    # width-sorted — a few thousand cells per chunk, bounded peak memory)
    plan = ShardPlan(scenario_chunk=args.shard or 8,
                     design_chunk=args.design_chunk or 32,
                     devices=args.devices, by_width=True)
    print(f"{spec.name}: {cells_of(spec)} cells "
          f"({len(spec.platforms)} platforms x {len(spec.scenarios)} "
          f"scenarios x {len(spec.designs)} designs), plan {plan}",
          file=sys.stderr)
    t0 = time.perf_counter()
    result = _run_spec(spec, plan)
    dt = time.perf_counter() - t0
    print(f"evaluated in {dt:.1f}s "
          f"({cells_of(spec) / dt:,.0f} cells/s)", file=sys.stderr)
    if args.csv:
        report.write_csv(args.csv, result.rows(), fmt=report.fmt_exact)
        print(f"rows -> {args.csv}", file=sys.stderr)
    if args.summary or not args.csv:
        print(json.dumps(result.summary(), indent=2))


def cmd_invert(args: argparse.Namespace) -> None:
    """Gradient-based inverse design: accepts a ``deepnvm.inverse/1``
    problem document or a bare sweepspec (the spec's corner grid becomes
    the relaxation's span; solver fields come from the flags)."""
    import dataclasses

    from repro import inverse

    raw = sys.stdin.read() if args.spec == "-" else open(args.spec).read()
    doc = json.loads(raw)
    if doc.get("schema") == inverse.SCHEMA:
        prob = inverse.InverseProblem.from_json(doc)
    else:
        prob = inverse.InverseProblem(
            sweep=SymbolicSweepSpec.from_json(doc),
            name=doc.get("name", "inverse"))
    # flags override the document's fields only when given
    over: dict = {}
    if args.objective is not None:
        over["objective"] = args.objective
    if args.iso_area:
        over["area_budget_mm2"] = "iso"
    elif args.budget is not None:
        over["area_budget_mm2"] = args.budget
    elif args.no_budget:
        over["area_budget_mm2"] = None
    if args.target is not None:
        over["target"] = args.target
    if args.include_dram:
        over["include_dram"] = True
    for field in ("starts", "iters", "lr", "seed"):
        if getattr(args, field) is not None:
            over[field] = getattr(args, field)
    if over:
        prob = dataclasses.replace(prob, **over)

    t0 = time.perf_counter()
    res = inverse.solve(prob)
    dt = time.perf_counter() - t0
    print(f"{prob.name}: {prob.starts} starts x {prob.iters} iters "
          f"in {dt:.1f}s", file=sys.stderr)
    print(res.summary(), file=sys.stderr)
    out = json.dumps(res.to_doc(), indent=2) + "\n"
    if args.json:
        with open(args.json, "w") as f:
            f.write(out)
        print(f"result -> {args.json}", file=sys.stderr)
    else:
        sys.stdout.write(out)


def cmd_show(args: argparse.Namespace) -> None:
    sym = _load(args.spec)
    spec = sym.resolve()
    axes = _axes(spec)
    print(f"{spec.name}: {axes['platforms']} platforms x "
          f"{axes['scenarios']} scenarios x {axes['designs']} designs, "
          f"baseline {spec.baseline_mem!r}")
    print("platforms:", ", ".join(p.name for p in spec.platforms))
    print("scenarios:", ", ".join(sym.scenarios))
    print("designs:")
    for p in spec.designs:
        print(f"  {p.mem}@{p.capacity_mb:g}MB @{p.node.name} "
              f"(group {p.group!r})")


# The zero-window default service backing ``answer``/``serve`` for direct
# library callers: same handler as the transports, but requests evaluate
# immediately (no coalescing delay) — the historical single-caller contract.
_default_service: SweepService | None = None
_default_lock = threading.Lock()


def _service() -> SweepService:
    global _default_service
    with _default_lock:
        if _default_service is None or _default_service.closed:
            _default_service = SweepService(window_ms=0.0)
        return _default_service


def answer(request: Mapping | str) -> dict:
    """One serve-mode request -> one response document."""
    return _service().handle(request)


def serve(in_stream=None, out_stream=None) -> int:
    """Long-lived JSONL loop: one request per line in, one response line
    out.  Engine caches persist for the life of the process, so a warm
    server answers repeated specs without re-evaluating anything."""
    return service_mod.serve_stdio(_service(), in_stream, out_stream)


def cmd_serve(args: argparse.Namespace) -> None:
    import signal

    stdio = args.stdin or not (args.http or args.unix)
    # Zero coalescing window for a pure stdin loop (one synchronous caller,
    # a window only adds latency); a small window once sockets are involved.
    window_ms = args.window_ms if args.window_ms is not None \
        else (0.0 if stdio and not (args.http or args.unix) else 5.0)
    svc = SweepService(window_ms=window_ms, max_batch=args.max_batch,
                      coalesce=not args.no_coalesce,
                      max_pending=args.max_pending,
                      max_body_bytes=args.max_body_bytes)
    if args.warmup or args.warmup_spec or args.compile_cache:
        info = svc.warmup(specs=tuple(args.warmup_spec or ()),
                          compile_cache_dir=args.compile_cache,
                          grid=args.warmup)
        print(f"warmup: {info['fold_shapes']} fold shapes, "
              f"{info.get('engine_tables', 0)} engine tables, "
              f"{len(info['specs'])} specs in {info['warmup_s']:.2f}s",
              file=sys.stderr)

    servers = []
    if args.http:
        host, _, port = args.http.rpartition(":")
        srv = service_mod.SweepHTTPServer(
            (host or "127.0.0.1", int(port)), svc)
        servers.append(srv)
        bound = srv.server_address
        print(f"listening on http://{bound[0]}:{bound[1]}",
              file=sys.stderr, flush=True)
    if args.unix:
        if service_mod.SweepUnixServer is None:
            raise SystemExit("unix sockets unsupported on this platform")
        srv = service_mod.SweepUnixServer(args.unix, svc)
        servers.append(srv)
        print(f"listening on unix:{args.unix}", file=sys.stderr, flush=True)

    def _terminate(signum, frame):  # noqa: ARG001 — signal signature
        raise SystemExit(0)

    signal.signal(signal.SIGTERM, _terminate)
    signal.signal(signal.SIGINT, _terminate)
    threads = [threading.Thread(target=srv.serve_forever, daemon=True)
               for srv in servers]
    for t in threads:
        t.start()
    try:
        if stdio:
            service_mod.serve_stdio(svc)
        else:
            threading.Event().wait()
    except (KeyboardInterrupt, SystemExit):
        pass
    finally:
        for srv in servers:
            srv.shutdown()
            srv.server_close()
        svc.close()
        if args.stats_on_exit:
            print(json.dumps(svc.stats(), indent=2), file=sys.stderr)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep",
        description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    run_p = sub.add_parser("run", help="evaluate a spec JSON document")
    run_p.add_argument("spec", help="path to spec.json ('-' for stdin)")
    run_p.add_argument("--csv", metavar="PATH",
                       help="write rows CSV here (default: stdout)")
    run_p.add_argument("--pareto", metavar="PATH",
                       help="also write the per-scenario Pareto front")
    run_p.add_argument("--plateaus", metavar="PATH",
                       help="also write capacity-plateau rows")
    run_p.add_argument("--summary", action="store_true",
                       help="print the aggregate summary as JSON")
    run_p.add_argument("--no-norm", action="store_true",
                       help="omit the normalized (*_x) columns")
    run_p.add_argument("--include-dram", action="store_true",
                       help="include DRAM terms in energy/EDP columns")
    _add_shard_flags(run_p)
    run_p.set_defaults(func=cmd_run)

    mega_p = sub.add_parser(
        "mega", help="run the full 1e5-cell DTCO cross product (sharded)")
    mega_p.add_argument("--quick", action="store_true",
                        help="CI-smoke size (a few hundred cells)")
    mega_p.add_argument("--csv", metavar="PATH",
                        help="write rows CSV here")
    mega_p.add_argument("--summary", action="store_true",
                        help="print the aggregate summary as JSON")
    _add_shard_flags(mega_p)
    mega_p.set_defaults(func=cmd_mega)

    inv_p = sub.add_parser(
        "invert",
        help="gradient-based inverse design over a spec's corner grid")
    inv_p.add_argument("spec", help="deepnvm.inverse/1 problem JSON or a "
                                    "sweepspec JSON ('-' for stdin)")
    inv_p.add_argument("--objective", choices=["edp", "edap"], default=None,
                       help="objective to minimize (default: the "
                            "document's, else edp)")
    inv_p.add_argument("--iso-area", action="store_true",
                       help="area budget = max grid-corner area (the "
                            "iso-area formulation)")
    inv_p.add_argument("--budget", type=float, metavar="MM2",
                       help="explicit area budget in mm^2")
    inv_p.add_argument("--no-budget", action="store_true",
                       help="drop the area constraint entirely")
    inv_p.add_argument("--target", type=float, metavar="VALUE",
                       help="target-hitting mode: drive the objective to "
                            "VALUE instead of minimizing")
    inv_p.add_argument("--include-dram", action="store_true",
                       help="include DRAM terms in the EDP objective")
    inv_p.add_argument("--starts", type=int, default=None, metavar="N",
                       help="multi-start batch size")
    inv_p.add_argument("--iters", type=int, default=None, metavar="N",
                       help="Adam iterations per start")
    inv_p.add_argument("--lr", type=float, default=None,
                       help="Adam learning rate (ln-leaf space)")
    inv_p.add_argument("--seed", type=int, default=None,
                       help="start-sampling seed")
    inv_p.add_argument("--json", metavar="PATH",
                       help="write the result document here (default: "
                            "stdout)")
    inv_p.set_defaults(func=cmd_invert)

    show_p = sub.add_parser("show", help="resolve a spec without running")
    show_p.add_argument("spec")
    show_p.set_defaults(func=cmd_show)

    serve_p = sub.add_parser(
        "serve",
        help="concurrent sweep service (stdin JSONL / HTTP / unix socket)")
    serve_p.add_argument("--http", metavar="HOST:PORT",
                         help="serve HTTP on this address (port 0 picks "
                              "an ephemeral port, printed to stderr)")
    serve_p.add_argument("--unix", metavar="PATH",
                         help="serve JSONL over a unix stream socket")
    serve_p.add_argument("--stdin", action="store_true",
                         help="also run the stdin JSONL loop alongside "
                              "socket transports (default when no "
                              "transport flag is given)")
    serve_p.add_argument("--window-ms", type=float, default=None,
                         metavar="MS",
                         help="coalescing window (default 5ms with a "
                              "socket transport, 0 for stdin-only)")
    serve_p.add_argument("--max-batch", type=int, default=64, metavar="N",
                         help="max requests merged per coalesced batch")
    serve_p.add_argument("--no-coalesce", action="store_true",
                         help="disable request coalescing")
    serve_p.add_argument("--max-pending", type=int, default=64, metavar="N",
                         help="evaluations admitted concurrently before "
                              "requests are refused with 429")
    serve_p.add_argument("--max-body-bytes", type=int, default=1 << 20,
                         metavar="N",
                         help="largest request document accepted (larger "
                              "bodies are refused with 413, unread)")
    serve_p.add_argument("--warmup", action="store_true",
                         help="pre-trace engine + fold kernels at the "
                              "registered pad-width buckets before serving")
    serve_p.add_argument("--warmup-spec", action="append", metavar="PATH",
                         help="pre-trace the exact shapes this spec needs "
                              "(repeatable)")
    serve_p.add_argument("--compile-cache", metavar="DIR",
                         help="enable the JAX persistent compilation "
                              "cache at DIR (survives restarts)")
    serve_p.add_argument("--stats-on-exit", action="store_true",
                         help="print the stats document to stderr on "
                              "shutdown")
    serve_p.set_defaults(func=cmd_serve)

    args = ap.parse_args(argv)
    args.func(args)


if __name__ == "__main__":
    main()

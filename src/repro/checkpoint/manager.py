"""Fault-tolerant checkpointing: atomic, async, keep-k, auto-resume.

Design (multi-host-ready, np-file based so it works offline):

  * each save goes to `<dir>/step_<N>.tmp/`, one .npy per flattened leaf
    plus a manifest (treedef + shapes + shardings as text), then the dir is
    atomically renamed to `step_<N>` — a crashed save can never be mistaken
    for a valid checkpoint.
  * saves run on a background thread (training continues; `wait()` joins).
  * `restore_latest` scans for the newest complete manifest, verifies leaf
    count/shape, and reports the step — the restart path after a node
    failure.  Corrupt/partial dirs are skipped (and reported).
  * on a real multi-pod deployment each host writes its addressable shards;
    here process 0 writes everything (single-process container).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(leaf) for leaf in leaves]

        def _write():
            tmp = os.path.join(self.directory, f"step_{step:010d}.tmp")
            final = os.path.join(self.directory, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "n_leaves": len(host_leaves),
                        "treedef": str(treedef),
                        "shapes": [list(a.shape) for a in host_leaves],
                        "dtypes": [str(a.dtype) for a in host_leaves]}
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):   # re-save of the same step
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic publish
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self._complete_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def _complete_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.directory, name,
                                           "manifest.json")):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self._complete_steps()
        return max(steps) if steps else None

    def restore(self, step: int, like):
        """Restore into the structure of `like` (validates leaf shapes)."""
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree_util.tree_flatten(like)
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves, "
                f"expected {len(leaves)} — incompatible tree")
        restored = []
        for i, ref in enumerate(leaves):
            arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
            if hasattr(ref, "shape") and tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(f"leaf {i}: shape {arr.shape} != "
                                 f"{ref.shape}")
            restored.append(arr)
        return jax.tree_util.tree_unflatten(treedef, restored)

    def restore_latest(self, like):
        """(step, tree) of the newest valid checkpoint, or (None, None)."""
        self.wait()
        for step in sorted(self._complete_steps(), reverse=True):
            try:
                return step, self.restore(step, like)
            except (ValueError, OSError) as e:  # corrupt: try the previous
                print(f"checkpoint step {step} unreadable ({e}); skipping")
        return None, None

"""Architecture-specific blocks: MoE, MLA (+MTP), Mamba/hybrid, RWKV6.

All blocks are pure functions over param pytrees, mesh-agnostic (sharding
is applied by distributed/sharding.py), and written with einsum dispatch /
lax.scan control flow so they lower to clean SPMD HLO.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.layers import Params, truncated_normal

# ---------------------------------------------------------------------------
# Mixture of Experts — GShard-style einsum dispatch (TPU-idiomatic)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0           # shared (always-on) experts
    group_size: int = 512       # tokens per dispatch group
    capacity_factor: float = 1.25
    router_bias: bool = True    # aux-loss-free bias (DeepSeek-V3 style)


def init_moe(key, dims: MoEDims) -> Params:
    kr, ke, ks = jax.random.split(key, 3)
    d, e, f = dims.d_model, dims.n_experts, dims.d_expert
    s_in, s_out = d ** -0.5, f ** -0.5
    ke1, ke2, ke3 = jax.random.split(ke, 3)
    p = {
        "router": truncated_normal(kr, (d, e), s_in),
        "wi_gate": truncated_normal(ke1, (e, d, f), s_in),
        "wi_up": truncated_normal(ke2, (e, d, f), s_in),
        "wo": truncated_normal(ke3, (e, f, d), s_out),
    }
    if dims.router_bias:
        p["router_bias"] = jnp.zeros((e,), jnp.float32)
    if dims.n_shared:
        p["shared"] = layers.init_mlp(ks, d, dims.n_shared * f)
    return p


def moe(p: Params, dims: MoEDims, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (out, aux_loss).  x: (B, S, d)."""
    b, s, d = x.shape
    t = b * s
    g_size = dims.group_size
    pad = (-t) % g_size
    x_flat = x.reshape(t, d)
    if pad:
        x_flat = jnp.concatenate(
            [x_flat, jnp.zeros((pad, d), x.dtype)], axis=0)
    valid = (jnp.arange(t + pad) < t).astype(jnp.float32) \
        .reshape(-1, g_size)                         # (G, S_g)
    xg = x_flat.reshape(-1, g_size, d)               # (G, S_g, d)
    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    routed = probs
    if "router_bias" in p:                           # bias only affects top-k
        routed = probs + p["router_bias"]
    gate_vals, expert_idx = jax.lax.top_k(routed, dims.top_k)  # (G,S,K)
    gates = jnp.take_along_axis(probs, expert_idx, axis=-1)
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    gates = gates * valid[..., None]                 # padding takes no slots

    e = dims.n_experts
    cap = int(g_size * dims.top_k / e * dims.capacity_factor) + 1
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)   # (G,S,K,E)
    onehot = onehot * valid[..., None, None]
    # position of each (token, slot) within its expert's capacity buffer
    pos = jnp.cumsum(onehot.reshape(onehot.shape[0], -1, e), axis=1)
    pos = pos.reshape(onehot.shape) - 1.0                        # (G,S,K,E)
    in_cap = pos < cap
    combine = (gates[..., None] * onehot * in_cap)               # (G,S,K,E)
    pos_idx = jnp.where(in_cap, pos, cap).astype(jnp.int32)      # (G,S,K,E)
    cap_oh = jax.nn.one_hot(pos_idx, cap, dtype=x.dtype)         # (G,S,K,E,C)
    combine_t = (combine.astype(x.dtype)[..., None] * cap_oh)    # (G,S,K,E,C)
    combine_t = combine_t.sum(axis=2)                            # (G,S,E,C)
    dispatch_t = (combine_t > 0).astype(x.dtype)

    exp_in = jnp.einsum("gsec,gsd->egcd", dispatch_t, xg)        # (E,G,C,d)
    gate_h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", exp_in,
                                    p["wi_gate"].astype(x.dtype)))
    up_h = jnp.einsum("egcd,edf->egcf", exp_in, p["wi_up"].astype(x.dtype))
    exp_out = jnp.einsum("egcf,efd->egcd", gate_h * up_h,
                         p["wo"].astype(x.dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine_t, exp_out)

    # load-balance auxiliary loss (Switch-style fraction*prob)
    frac = jnp.mean(onehot, axis=(1, 2))                          # (G,E)
    mean_prob = jnp.mean(probs, axis=1)                           # (G,E)
    aux = jnp.mean(jnp.sum(frac * mean_prob, axis=-1)) * e

    out = out.reshape(t + pad, d)[:t].reshape(b, s, d)
    if "shared" in p:
        out = out + layers.mlp(p["shared"], x)
    return out, aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V3) + MTP head
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLADims:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim


def init_mla(key, dims: MLADims) -> Params:
    ks = jax.random.split(key, 8)
    d, h = dims.d_model, dims.n_heads
    r_q, r_kv = dims.q_lora_rank, dims.kv_lora_rank
    return {
        "wq_a": truncated_normal(ks[0], (d, r_q), d ** -0.5),
        "q_norm": layers.init_rmsnorm(r_q),
        "wq_b": truncated_normal(ks[1], (r_q, h, dims.qk_dim), r_q ** -0.5),
        "wkv_a": truncated_normal(ks[2], (d, r_kv + dims.qk_rope_dim), d ** -0.5),
        "kv_norm": layers.init_rmsnorm(r_kv),
        "wk_b": truncated_normal(ks[3], (r_kv, h, dims.qk_nope_dim), r_kv ** -0.5),
        "wv_b": truncated_normal(ks[4], (r_kv, h, dims.v_head_dim), r_kv ** -0.5),
        "wo": truncated_normal(ks[5], (h, dims.v_head_dim, d),
                               (h * dims.v_head_dim) ** -0.5),
    }


def mla_attention(p: Params, dims: MLADims, x: jax.Array,
                  positions: jax.Array, *, kv_cache=None, cache_index=None):
    """MLA with compressed-latent KV cache.  Cache = {"ckv": (B,S,r_kv),
    "krope": (B,S,rope_dim)} — the memory win vs vanilla GQA.  Decode uses
    the absorbed-matmul form (attend directly in latent space)."""
    b, s, _ = x.shape
    scale = dims.qk_dim ** -0.5
    q_lat = layers.rmsnorm(p["q_norm"],
                           jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)))
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :dims.qk_nope_dim], q[..., dims.qk_nope_dim:]
    q_rope = layers.apply_rope(q_rope, positions, dims.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    ckv_new = layers.rmsnorm(p["kv_norm"], kv_a[..., :dims.kv_lora_rank])
    krope_new = layers.apply_rope(kv_a[..., dims.kv_lora_rank:][:, :, None, :],
                                  positions, dims.rope_theta)[:, :, 0, :]

    new_cache = None
    if kv_cache is not None:
        ckv = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["ckv"], ckv_new.astype(kv_cache["ckv"].dtype), cache_index, 1)
        krope = jax.lax.dynamic_update_slice_in_dim(
            kv_cache["krope"], krope_new.astype(kv_cache["krope"].dtype),
            cache_index, 1)
        new_cache = {"ckv": ckv, "krope": krope}
        q_offset = cache_index
    else:
        ckv, krope = ckv_new, krope_new
        q_offset = 0

    # absorbed form: q_nope -> latent space, attend against ckv directly.
    # Keys/values stay SHARED across heads (one latent stream) — the MLA
    # memory saving; the flash path understands the single-kv-head layout.
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, p["wk_b"].astype(x.dtype))
    q_eff = jnp.concatenate([q_abs, q_rope], axis=-1)        # (B,S,H,r+rope)
    k_eff = jnp.concatenate([ckv, krope], axis=-1)[:, :, None, :]
    v_eff = ckv[:, :, None, :]
    if q_eff.shape[1] == 1 or q_eff.shape[1] < 2048:
        logits = jnp.einsum("bqhr,bkr->bhqk", q_eff, k_eff[:, :, 0, :],
                            preferred_element_type=jnp.float32) * scale
        sq, skv = q.shape[1], ckv.shape[1]
        q_pos = jnp.arange(sq) + q_offset
        mask = q_pos[:, None] >= jnp.arange(skv)[None, :]
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhqk,bkr->bqhr", probs, ckv)
    else:
        from repro.kernels import ops as kops
        ctx_lat = kops.attention(q_eff, k_eff, v_eff, causal=True,
                                 q_offset=q_offset, scale=scale,
                                 force="ref").astype(x.dtype)
    v = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, p["wv_b"].astype(x.dtype))
    out = jnp.einsum("bqhd,hdo->bqo", v, p["wo"].astype(x.dtype))
    return out, new_cache


def init_mla_cache(batch: int, max_seq: int, dims: MLADims,
                   dtype=jnp.bfloat16) -> Params:
    return {"ckv": jnp.zeros((batch, max_seq, dims.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, max_seq, dims.qk_rope_dim), dtype)}


# ---------------------------------------------------------------------------
# Selective SSM (Mamba-style) + Hymba parallel attn/SSM block
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_inner: int
    state_dim: int = 16
    conv_k: int = 4
    dt_rank: int = 0  # 0 -> d_model // 16

    @property
    def dtr(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)


def init_ssm(key, dims: SSMDims) -> Params:
    ks = jax.random.split(key, 7)
    d, di, n = dims.d_model, dims.d_inner, dims.state_dim
    return {
        "in_proj": truncated_normal(ks[0], (d, 2 * di), d ** -0.5),
        "conv": truncated_normal(ks[1], (dims.conv_k, di), 0.5),
        "x_proj": truncated_normal(ks[2], (di, dims.dtr + 2 * n), di ** -0.5),
        "dt_proj": truncated_normal(ks[3], (dims.dtr, di), dims.dtr ** -0.5),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": truncated_normal(ks[4], (di, d), di ** -0.5),
    }


def ssm(p: Params, dims: SSMDims, x: jax.Array, *, state=None):
    """Selective scan.  state (decode): {"conv": (B,K-1,di), "h": (B,di,N)}.
    Returns (out, new_state_or_None)."""
    b, s, _ = x.shape
    di, n = dims.d_inner, dims.state_dim
    ux, z = jnp.split(jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype)),
                      2, axis=-1)
    # depthwise causal conv
    if state is not None:
        conv_in = jnp.concatenate([state["conv"].astype(ux.dtype), ux], axis=1)
        new_conv = conv_in[:, -(dims.conv_k - 1):, :]
    else:
        pad = jnp.zeros((b, dims.conv_k - 1, di), ux.dtype)
        conv_in = jnp.concatenate([pad, ux], axis=1)
        new_conv = conv_in[:, -(dims.conv_k - 1):, :]
    kern = p["conv"].astype(ux.dtype)
    u = sum(conv_in[:, i:i + s, :] * kern[i] for i in range(dims.conv_k))
    u = jax.nn.silu(u)

    proj = jnp.einsum("bse,ef->bsf", u, p["x_proj"].astype(u.dtype))
    dt = jax.nn.softplus(jnp.einsum(
        "bsr,re->bse", proj[..., :dims.dtr], p["dt_proj"].astype(u.dtype))
        .astype(jnp.float32))                                    # (B,S,di)
    bmat = proj[..., dims.dtr:dims.dtr + n].astype(jnp.float32)  # (B,S,N)
    cmat = proj[..., dims.dtr + n:].astype(jnp.float32)          # (B,S,N)
    a = -jnp.exp(p["a_log"])                                     # (di,N)

    decay = jnp.exp(dt[..., None] * a)                           # (B,S,di,N)
    drive = (dt * u.astype(jnp.float32))[..., None] * bmat[:, :, None, :]

    h0 = (state["h"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, di, n), jnp.float32))

    def step(h, inp):
        dec, drv, c = inp
        h = dec * h + drv
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    xs = (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(drive, 1, 0),
          jnp.moveaxis(cmat, 1, 0))
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(u.dtype)                   # (B,S,di)
    y = y + u * p["d_skip"].astype(u.dtype)
    out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z),
                     p["out_proj"].astype(x.dtype))
    new_state = {"conv": new_conv.astype(jnp.bfloat16),
                 "h": h_last.astype(jnp.bfloat16)}
    return out, new_state


def init_ssm_state(batch: int, dims: SSMDims) -> Params:
    return {"conv": jnp.zeros((batch, dims.conv_k - 1, dims.d_inner),
                              jnp.bfloat16),
            "h": jnp.zeros((batch, dims.d_inner, dims.state_dim),
                           jnp.bfloat16)}


# ---------------------------------------------------------------------------
# RWKV6 (Finch): time-mix with data-dependent decay + channel-mix
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RWKVDims:
    d_model: int
    n_heads: int           # head_dim = d_model // n_heads
    d_ff: int
    decay_lora: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def init_rwkv_tmix(key, dims: RWKVDims) -> Params:
    ks = jax.random.split(key, 8)
    d = dims.d_model
    s = d ** -0.5
    return {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": truncated_normal(ks[0], (d, d), s),
        "wk": truncated_normal(ks[1], (d, d), s),
        "wv": truncated_normal(ks[2], (d, d), s),
        "wg": truncated_normal(ks[3], (d, d), s),
        "w0": jnp.full((d,), -5.0, jnp.float32),
        "w_lora_a": truncated_normal(ks[4], (d, dims.decay_lora), s),
        "w_lora_b": truncated_normal(ks[5], (dims.decay_lora, d),
                                     dims.decay_lora ** -0.5),
        "bonus": jnp.zeros((dims.n_heads, dims.head_dim), jnp.float32),
        "ln_out": layers.init_rmsnorm(d),
        "wo": truncated_normal(ks[6], (d, d), s),
    }


def rwkv_tmix(p: Params, dims: RWKVDims, x: jax.Array, *, state=None):
    """WKV6 recurrence.  state: {"last_x": (B,d), "s": (B,H,hd,hd)}."""
    b, s_len, d = x.shape
    h, hd = dims.n_heads, dims.head_dim
    last_x = (state["last_x"].astype(x.dtype) if state is not None
              else jnp.zeros((b, d), x.dtype))
    x_prev = jnp.concatenate([last_x[:, None, :], x[:, :-1, :]], axis=1)

    def mix(mu):
        return x + (x_prev - x) * mu.astype(x.dtype)

    r = jnp.einsum("bsd,de->bse", mix(p["mu_r"]), p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", mix(p["mu_k"]), p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", mix(p["mu_v"]), p["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", x, p["wg"].astype(x.dtype)))
    # data-dependent decay (the Finch contribution)
    w_in = mix(p["mu_w"]).astype(jnp.float32)
    w = p["w0"] + jnp.einsum("bsd,dr,re->bse", w_in, p["w_lora_a"],
                             p["w_lora_b"])
    w = jnp.exp(-jnp.exp(w))                                     # (B,S,d)

    rh = r.reshape(b, s_len, h, hd).astype(jnp.float32)
    kh = k.reshape(b, s_len, h, hd).astype(jnp.float32)
    vh = v.reshape(b, s_len, h, hd).astype(jnp.float32)
    wh = w.reshape(b, s_len, h, hd)
    u = p["bonus"]                                               # (H,hd)

    s0 = (state["s"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, h, hd, hd), jnp.float32))

    def step(s_carry, inp):
        rt, kt, vt, wt = inp                                     # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]                 # (B,H,hd,hd)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s_carry + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s_carry + kv
        return s_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rh, kh, vh, wh))
    s_last, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s_len, d).astype(x.dtype)
    y = layers.rmsnorm(p["ln_out"], y) * g
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(x.dtype))
    new_state = {"last_x": x[:, -1, :].astype(jnp.bfloat16),
                 "s": s_last.astype(jnp.bfloat16)}
    return out, new_state


def init_rwkv_cmix(key, dims: RWKVDims) -> Params:
    k1, k2 = jax.random.split(key)
    d = dims.d_model
    return {
        "mu": jnp.full((d,), 0.5, jnp.float32),
        "wk": truncated_normal(k1, (d, dims.d_ff), d ** -0.5),
        "wv": truncated_normal(k2, (dims.d_ff, d), dims.d_ff ** -0.5),
    }


def rwkv_cmix(p: Params, dims: RWKVDims, x: jax.Array, *, state=None):
    b, s_len, d = x.shape
    last_x = (state["last_x"].astype(x.dtype) if state is not None
              else jnp.zeros((b, d), x.dtype))
    x_prev = jnp.concatenate([last_x[:, None, :], x[:, :-1, :]], axis=1)
    xm = x + (x_prev - x) * p["mu"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xm, p["wk"].astype(x.dtype))))
    out = jnp.einsum("bsf,fd->bsd", k, p["wv"].astype(x.dtype))
    return out, {"last_x": x[:, -1, :].astype(jnp.bfloat16)}


def init_rwkv_state(batch: int, dims: RWKVDims) -> Params:
    return {
        "tmix": {"last_x": jnp.zeros((batch, dims.d_model), jnp.bfloat16),
                 "s": jnp.zeros((batch, dims.n_heads, dims.head_dim,
                                 dims.head_dim), jnp.bfloat16)},
        "cmix": {"last_x": jnp.zeros((batch, dims.d_model), jnp.bfloat16)},
    }

"""LM assembly: layer plans, scan-over-layers, train/prefill/decode.

Every architecture is a sequence of *segments*; a segment is `count`
identical blocks whose params are stacked on a leading layer axis and
applied with `jax.lax.scan` (compact HLO, fast compiles at 512-way SPMD).
Heterogeneous architectures (leading dense layers in DeepSeek MoEs, the
three global-attention layers in Hymba) are expressed as multiple segments.

Block kinds:
  dense        pre-norm GQA attention + gated MLP
  moe          pre-norm attention (GQA or MLA) + MoE FFN
  hybrid       parallel attention/SSM heads (Hymba), SWA or global
  rwkv         RWKV6 time-mix + channel-mix
  encoder      non-causal dense block (Whisper encoder)
  crossdec     causal self-attn + cross-attn + MLP (Whisper decoder)
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks, layers
from repro.models.layers import AttnDims, Params

# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    count: int
    window: int | None = None   # sliding window for hybrid SWA segments


def layer_plan(cfg: ArchConfig) -> tuple[Segment, ...]:
    if cfg.encdec is not None:
        return (Segment("crossdec", cfg.n_layers),)
    if cfg.rwkv:
        return (Segment("rwkv", cfg.n_layers),)
    if cfg.ssm is not None:   # Hymba hybrid: split on global-attn layers
        segs: list[Segment] = []
        glb = set(cfg.ssm.global_attn_layers)
        i = 0
        while i < cfg.n_layers:
            if i in glb:
                segs.append(Segment("hybrid", 1, window=None))
                i += 1
            else:
                j = i
                while j < cfg.n_layers and j not in glb:
                    j += 1
                segs.append(Segment("hybrid", j - i,
                                    window=cfg.ssm.sliding_window))
                i = j
        return tuple(segs)
    if cfg.moe is not None:
        segs = []
        if cfg.moe.first_dense_layers:
            segs.append(Segment("dense_lead", cfg.moe.first_dense_layers))
        segs.append(Segment("moe", cfg.n_layers - cfg.moe.first_dense_layers))
        return tuple(segs)
    return (Segment("dense", cfg.n_layers),)


# ---------------------------------------------------------------------------
# Dim helpers
# ---------------------------------------------------------------------------


def attn_dims(cfg: ArchConfig, window: int | None = None) -> AttnDims:
    return AttnDims(d_model=cfg.d_model, n_heads=cfg.n_heads,
                    n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                    qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
                    window=window)


def moe_dims(cfg: ArchConfig) -> blocks.MoEDims:
    m = cfg.moe
    return blocks.MoEDims(d_model=cfg.d_model, n_experts=m.n_experts,
                          top_k=m.top_k, d_expert=m.d_expert,
                          n_shared=m.n_shared, group_size=m.group_size,
                          capacity_factor=m.capacity_factor)


def mla_dims(cfg: ArchConfig) -> blocks.MLADims:
    m = cfg.mla
    return blocks.MLADims(d_model=cfg.d_model, n_heads=cfg.n_heads,
                          q_lora_rank=m.q_lora_rank, kv_lora_rank=m.kv_lora_rank,
                          qk_nope_dim=m.qk_nope_dim, qk_rope_dim=m.qk_rope_dim,
                          v_head_dim=m.v_head_dim, rope_theta=cfg.rope_theta)


def ssm_dims(cfg: ArchConfig) -> blocks.SSMDims:
    return blocks.SSMDims(d_model=cfg.d_model, d_inner=cfg.d_model,
                          state_dim=cfg.ssm.state_dim, conv_k=cfg.ssm.conv_k)


def rwkv_dims(cfg: ArchConfig) -> blocks.RWKVDims:
    return blocks.RWKVDims(d_model=cfg.d_model, n_heads=cfg.n_heads,
                           d_ff=cfg.d_ff)


# ---------------------------------------------------------------------------
# Per-block init / apply
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, seg: Segment) -> Params:
    ka, kf, kx = jax.random.split(key, 3)
    d = cfg.d_model
    p: Params = {"ln_attn": layers.init_rmsnorm(d),
                 "ln_mlp": layers.init_rmsnorm(d)}
    if seg.kind == "rwkv":
        return {"ln_tmix": layers.init_rmsnorm(d),
                "ln_cmix": layers.init_rmsnorm(d),
                "tmix": blocks.init_rwkv_tmix(ka, rwkv_dims(cfg)),
                "cmix": blocks.init_rwkv_cmix(kf, rwkv_dims(cfg))}
    if cfg.mla is not None and seg.kind in ("moe", "dense_lead"):
        p["attn"] = blocks.init_mla(ka, mla_dims(cfg))
    else:
        p["attn"] = layers.init_attention(ka, attn_dims(cfg, seg.window))
    if seg.kind == "moe":
        p["ffn"] = blocks.init_moe(kf, moe_dims(cfg))
    elif seg.kind == "dense_lead":
        p["ffn"] = layers.init_mlp(kf, d, cfg.moe.dense_d_ff)
    elif seg.kind == "crossdec":
        p["ffn"] = layers.init_mlp(kf, d, cfg.d_ff)
        p["ln_cross"] = layers.init_rmsnorm(d)
        p["cross"] = layers.init_attention(kx, attn_dims(cfg))
    else:
        p["ffn"] = layers.init_mlp(kf, d, cfg.d_ff)
    if seg.kind == "hybrid":
        p["ssm"] = blocks.init_ssm(kx, ssm_dims(cfg))
        p["ln_attn_out"] = layers.init_rmsnorm(d)
        p["ln_ssm_out"] = layers.init_rmsnorm(d)
    return p


def _apply_block(lp: Params, cfg: ArchConfig, seg: Segment, x: jax.Array,
                 positions: jax.Array, *, causal: bool = True,
                 cache=None, cache_index=None, cross_ctx=None):
    """Returns (x, aux_loss, new_cache)."""
    from repro.distributed.sharding import constrain
    x = constrain(x, "residual")   # pin the scan carry's layout
    rs = jnp.asarray(cfg.residual_scale, x.dtype)
    aux = jnp.zeros((), jnp.float32)

    if seg.kind == "rwkv":
        t_in = layers.rmsnorm(lp["ln_tmix"], x)
        t_out, t_state = blocks.rwkv_tmix(
            lp["tmix"], rwkv_dims(cfg), t_in,
            state=None if cache is None else cache["tmix"])
        x = x + t_out
        c_in = layers.rmsnorm(lp["ln_cmix"], x)
        c_out, c_state = blocks.rwkv_cmix(
            lp["cmix"], rwkv_dims(cfg), c_in,
            state=None if cache is None else cache["cmix"])
        x = x + c_out
        new_cache = None if cache is None else {"tmix": t_state,
                                                "cmix": c_state}
        return x, aux, new_cache

    h = layers.rmsnorm(lp["ln_attn"], x)
    new_cache = {} if cache is not None else None
    if seg.kind == "hybrid":
        attn_out, kvc = layers.attention(
            lp["attn"], attn_dims(cfg, seg.window), h, positions,
            causal=causal,
            kv_cache=None if cache is None else cache["kv"],
            cache_index=cache_index)
        ssm_out, ssm_state = blocks.ssm(
            lp["ssm"], ssm_dims(cfg), h,
            state=None if cache is None else cache["ssm"])
        mixed = 0.5 * (layers.rmsnorm(lp["ln_attn_out"], attn_out)
                       + layers.rmsnorm(lp["ln_ssm_out"], ssm_out))
        x = x + rs * mixed
        if cache is not None:
            new_cache = {"kv": kvc, "ssm": ssm_state}
    elif cfg.mla is not None and seg.kind in ("moe", "dense_lead"):
        attn_out, kvc = blocks.mla_attention(
            lp["attn"], mla_dims(cfg), h, positions,
            kv_cache=None if cache is None else cache["kv"],
            cache_index=cache_index)
        x = x + rs * attn_out
        if cache is not None:
            new_cache = {"kv": kvc}
    else:
        attn_out, kvc = layers.attention(
            lp["attn"], attn_dims(cfg, seg.window), h, positions,
            causal=causal,
            kv_cache=None if cache is None else cache["kv"],
            cache_index=cache_index)
        x = x + rs * attn_out
        if cache is not None:
            new_cache = {"kv": kvc}

    if seg.kind == "crossdec" and cross_ctx is not None:
        hc = layers.rmsnorm(lp["ln_cross"], x)
        cross_out = _cross_attention(lp["cross"], cfg, hc, cross_ctx)
        x = x + rs * cross_out

    h2 = layers.rmsnorm(lp["ln_mlp"], x)
    if seg.kind == "moe":
        ffn_out, aux = blocks.moe(lp["ffn"], moe_dims(cfg), h2)
    else:
        ffn_out = layers.mlp(lp["ffn"], h2, cfg.activation)
    x = x + rs * ffn_out
    return x, aux, new_cache


def _cross_attention(p: Params, cfg: ArchConfig, x: jax.Array,
                     ctx: jax.Array) -> jax.Array:
    dims = attn_dims(cfg)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", ctx, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", ctx, p["wv"].astype(x.dtype))
    out = layers.attention_scores(q, layers._expand_kv(k, dims.n_heads),
                                  layers._expand_kv(v, dims.n_heads),
                                  causal=False, window=None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _init_block_cache(cfg: ArchConfig, seg: Segment, batch: int,
                      max_seq: int, dtype=jnp.bfloat16) -> Params:
    if seg.kind == "rwkv":
        return blocks.init_rwkv_state(batch, rwkv_dims(cfg))
    cache: Params = {}
    if cfg.mla is not None and seg.kind in ("moe", "dense_lead"):
        cache["kv"] = blocks.init_mla_cache(batch, max_seq, mla_dims(cfg),
                                            dtype=dtype)
    else:
        cache["kv"] = layers.init_kv_cache(batch, max_seq,
                                           attn_dims(cfg, seg.window),
                                           dtype=dtype)
    if seg.kind == "hybrid":
        cache["ssm"] = blocks.init_ssm_state(batch, ssm_dims(cfg))
    return cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class LM:
    """Decoder LM (all archs except Whisper, which subclasses).

    `remat` controls per-layer activation checkpointing inside the layer
    scan (training path only; serving never pays recompute):
      "none"  — save everything (smallest compute, largest memory)
      "dots"  — save matmul outputs only (jax dots_saveable)
      "full"  — save nothing, recompute the block in backward (default:
                 the memory floor that makes the 4k/32k cells fit HBM)
    """

    def __init__(self, cfg: ArchConfig, remat: str = "full",
                 kv_cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.remat = remat
        # fp8 (e4m3) halves KV-cache HBM footprint and decode read traffic
        # (SSPerf memory-term lever for decode cells); attention math still
        # runs in bf16/f32 (cache values upcast on read).
        self.kv_cache_dtype = kv_cache_dtype
        self.plan = layer_plan(cfg)

    def _maybe_remat(self, fn, has_cache: bool):
        if has_cache or self.remat == "none":
            return fn
        policy = (jax.checkpoint_policies.dots_saveable
                  if self.remat == "dots" else None)
        return jax.checkpoint(fn, policy=policy)

    # -- init ---------------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        keys = jax.random.split(key, len(self.plan) + 3)
        params: Params = {
            "embed": layers.init_embed(keys[0], cfg.vocab, cfg.d_model,
                                       tied=cfg.tied_embeddings),
            "ln_f": layers.init_rmsnorm(cfg.d_model),
        }
        for i, seg in enumerate(self.plan):
            seg_keys = jax.random.split(keys[i + 1], seg.count)
            params[f"seg{i}"] = jax.vmap(
                partial(_init_block, cfg=cfg, seg=seg))(seg_keys)
        if cfg.mtp:
            params["mtp"] = {
                "proj": layers.truncated_normal(
                    keys[-2], (2 * cfg.d_model, cfg.d_model),
                    (2 * cfg.d_model) ** -0.5),
                "block": _init_block(keys[-1], cfg,
                                     Segment("dense_lead", 1)
                                     if cfg.moe else Segment("dense", 1)),
                "ln": layers.init_rmsnorm(cfg.d_model),
            }
        return params

    # -- segments -----------------------------------------------------------

    def _run_segment(self, seg_params, cfg, seg, x, positions, *,
                     causal=True, cache=None, cache_index=None,
                     cross_ctx=None):
        """Scan `seg.count` stacked blocks; returns (x, aux, new_cache)."""
        block = self._maybe_remat(
            partial(_apply_block, cfg=cfg, seg=seg, causal=causal,
                    cache_index=cache_index, cross_ctx=cross_ctx),
            has_cache=cache is not None)

        if seg.count == 1:
            lp = jax.tree.map(lambda a: a[0], seg_params)
            c = None if cache is None else jax.tree.map(lambda a: a[0], cache)
            x, aux, nc = block(lp, x=x, positions=positions, cache=c)
            nc = None if nc is None else jax.tree.map(
                lambda a: a[None], nc)
            return x, aux, nc

        if cache is None:
            def body_nocache(carry, lp):
                xx, aux, _ = block(lp, x=carry, positions=positions,
                                   cache=None)
                return xx, aux
            x, auxs = jax.lax.scan(body_nocache, x, seg_params)
            return x, jnp.sum(auxs), None

        def body(carry, xs):
            lp, c = xs
            xx, aux, nc = block(lp, x=carry, positions=positions, cache=c)
            return xx, (aux, nc)

        x, (auxs, new_cache) = jax.lax.scan(body, x, (seg_params, cache))
        return x, jnp.sum(auxs), new_cache

    # -- forward ------------------------------------------------------------

    def forward(self, params: Params, tokens: jax.Array,
                positions: jax.Array | None = None,
                cache=None, cache_index=None):
        """Returns (logits, aux, new_cache)."""
        cfg = self.cfg
        if positions is None:
            base = 0 if cache_index is None else cache_index
            positions = base + jnp.arange(tokens.shape[1])[None, :]
        from repro.distributed.sharding import constrain
        scale = cfg.d_model ** 0.5 if cfg.embed_scale_by_dim else 1.0
        x = constrain(layers.embed(params["embed"], tokens, scale),
                      "residual")
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = {}
        for i, seg in enumerate(self.plan):
            c = None if cache is None else cache[f"seg{i}"]
            x, aux, nc = self._run_segment(
                params[f"seg{i}"], cfg, seg, x, positions,
                cache=c, cache_index=cache_index)
            x = constrain(x, "residual")
            aux_total = aux_total + aux
            if cache is not None:
                new_caches[f"seg{i}"] = nc
        x = layers.rmsnorm(params["ln_f"], x)
        logits = layers.unembed(params["embed"], x,
                                cap=cfg.logit_cap or None)
        return logits, aux_total, (new_caches if cache is not None else None)

    # -- losses -------------------------------------------------------------

    def loss(self, params: Params, batch: dict) -> jax.Array:
        """Next-token CE (+ MoE aux + MTP head when configured)."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        logits, aux, _ = self.forward(params, tokens)
        loss = layers.cross_entropy(logits, labels)
        if cfg.moe is not None:
            loss = loss + 0.01 * aux
        if cfg.mtp:
            loss = loss + 0.3 * self._mtp_loss(params, tokens, labels)
        return loss

    def _mtp_loss(self, params, tokens, labels):
        """DeepSeek-V3 MTP: predict t+2 from [h_t ; emb(label_t)]."""
        cfg = self.cfg
        mtp = params["mtp"]
        scale = cfg.d_model ** 0.5 if cfg.embed_scale_by_dim else 1.0
        x = layers.embed(params["embed"], tokens, scale)
        lbl_emb = layers.embed(params["embed"], labels, scale)
        h = jnp.concatenate([x, lbl_emb], axis=-1)
        h = jnp.einsum("bsd,de->bse", h, mtp["proj"].astype(x.dtype))
        positions = jnp.arange(tokens.shape[1])[None, :]
        seg = Segment("dense_lead", 1) if cfg.moe else Segment("dense", 1)
        h, _, _ = _apply_block(mtp["block"], cfg, seg, h, positions)
        h = layers.rmsnorm(mtp["ln"], h)
        logits = layers.unembed(params["embed"], h, cap=cfg.logit_cap or None)
        # next-next-token targets
        tgt = jnp.concatenate([labels[:, 1:], labels[:, -1:]], axis=1)
        return layers.cross_entropy(logits, tgt)

    # -- serving ------------------------------------------------------------

    def init_cache(self, batch: int, max_seq: int) -> Params:
        cache: Params = {}
        for i, seg in enumerate(self.plan):
            per_layer = [_init_block_cache(self.cfg, seg, batch, max_seq,
                                           dtype=self.kv_cache_dtype)
                         for _ in range(seg.count)]
            cache[f"seg{i}"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *per_layer)
        return cache

    def prefill(self, params: Params, tokens: jax.Array, cache: Params):
        logits, _, cache = self.forward(params, tokens, cache=cache,
                                        cache_index=0)
        return logits[:, -1:], cache

    def decode_step(self, params: Params, tokens: jax.Array, cache: Params,
                    index: jax.Array):
        """tokens: (B, 1) — one decode step at absolute position `index`."""
        logits, _, cache = self.forward(params, tokens, cache=cache,
                                        cache_index=index)
        return logits, cache


class WhisperLM(LM):
    """Encoder-decoder: encoder over stub frame embeddings + cross-attn
    decoder.  Inputs carry `frames`: (B, n_frames, d_model)."""

    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        self.enc_seg = Segment("dense", cfg.encdec.n_encoder_layers)

    def init(self, key) -> Params:
        k_dec, k_enc = jax.random.split(key)
        params = super().init(k_dec)
        seg_keys = jax.random.split(k_enc, self.enc_seg.count)
        params["encoder"] = jax.vmap(
            partial(_init_block, cfg=self.cfg, seg=self.enc_seg))(seg_keys)
        params["ln_enc"] = layers.init_rmsnorm(self.cfg.d_model)
        return params

    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        positions = jnp.arange(frames.shape[1])[None, :]
        x, _, _ = self._run_segment(params["encoder"], self.cfg,
                                    self.enc_seg, frames, positions,
                                    causal=False)
        return layers.rmsnorm(params["ln_enc"], x)

    def forward(self, params: Params, tokens: jax.Array,
                positions=None, cache=None, cache_index=None,
                frames: jax.Array | None = None, enc_out=None):
        cfg = self.cfg
        if enc_out is None:
            enc_out = self.encode(params, frames)
        if positions is None:
            base = 0 if cache_index is None else cache_index
            positions = base + jnp.arange(tokens.shape[1])[None, :]
        x = layers.embed(params["embed"], tokens)
        aux = jnp.zeros((), jnp.float32)
        new_caches = {}
        for i, seg in enumerate(self.plan):
            c = None if cache is None else cache[f"seg{i}"]
            x, a, nc = self._run_segment(params[f"seg{i}"], cfg, seg, x,
                                         positions, cache=c,
                                         cache_index=cache_index,
                                         cross_ctx=enc_out)
            aux = aux + a
            if cache is not None:
                new_caches[f"seg{i}"] = nc
        x = layers.rmsnorm(params["ln_f"], x)
        logits = layers.unembed(params["embed"], x)
        return logits, aux, (new_caches if cache is not None else None)

    def loss(self, params: Params, batch: dict) -> jax.Array:
        logits, _, _ = self.forward(params, batch["tokens"],
                                    frames=batch["frames"])
        return layers.cross_entropy(logits, batch["labels"])

    def prefill(self, params, tokens, cache, frames=None):
        logits, _, cache = self.forward(params, tokens, cache=cache,
                                        cache_index=0, frames=frames)
        return logits[:, -1:], cache

    def decode_step(self, params, tokens, cache, index, enc_out=None,
                    frames=None):
        logits, _, cache = self.forward(params, tokens, cache=cache,
                                        cache_index=index, frames=frames,
                                        enc_out=enc_out)
        return logits, cache


def build(cfg: ArchConfig) -> LM:
    return WhisperLM(cfg) if cfg.encdec is not None else LM(cfg)

"""Shared transformer layers: norms, RoPE, GQA attention, gated MLPs.

Conventions:
  * params are nested dicts of jnp arrays; init_* return (params, ...).
  * activations are bf16 by default, params fp32 master + bf16 compute
    (cast at use); all einsum contractions accumulate in fp32 where it
    matters (attention logits, norms).
  * `sharding hints` are applied by the caller (distributed/sharding.py)
    via named-sharding on params and with_sharding_constraint on
    activations — layers stay mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

Params = dict


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * scale


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"]).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (.., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / sliding window / KV cache decode)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int | None = None      # sliding-window size (None = global)
    softmax_scale: float | None = None


def init_attention(key, dims: AttnDims) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, hd = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    s = d ** -0.5
    p = {
        "wq": truncated_normal(kq, (d, h, hd), s),
        "wk": truncated_normal(kk, (d, kvh, hd), s),
        "wv": truncated_normal(kv, (d, kvh, hd), s),
        "wo": truncated_normal(ko, (h, hd, d), (h * hd) ** -0.5),
    }
    if dims.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B,S,KV,hd) -> (B,S,H,hd) by repeating groups (GQA)."""
    reps = n_heads // k.shape[-2]
    if reps == 1:
        return k
    return jnp.repeat(k, reps, axis=-2)


def attention_scores(q, k, v, *, causal: bool, window: int | None,
                     q_offset: jax.Array | int = 0,
                     k_positions: jax.Array | None = None,
                     scale: float | None = None) -> jax.Array:
    """Reference SDPA used for training/prefill (and as kernels/ref oracle).

    q: (B,Sq,H,hd); k,v: (B,Skv,H,hd).  q_offset positions q within kv;
    k_positions overrides the absolute key positions (ring-buffer decode:
    -1 marks an unwritten slot).
    """
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else scale
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    sq, skv = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(skv) if k_positions is None else k_positions
    mask = k_pos[None, :] >= 0
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _grouped_decode_attention(q, ck, cv, *, cache_index, window,
                              scale=None):
    """Single-token GQA decode WITHOUT expanding kv to query heads.

    Expanding via jnp.repeat forces GSPMD to all-gather the whole (possibly
    sequence-sharded) cache every step — the dominant decode collective in
    the baseline dry-runs.  The grouped einsum keeps the cache sharded; the
    softmax/PV reductions over the sharded seq dim lower to all-reduces of
    the (tiny) per-head outputs instead.
    q: (B,1,H,hd); ck/cv: (B,S,KV,hd).
    """
    b, _, h, hd = q.shape
    skv, g = ck.shape[1], ck.shape[2]
    rep = h // g
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(b, 1, g, rep, hd)
    logits = jnp.einsum("bqgrd,bkgd->bgrqk", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    k_pos = jnp.arange(skv)
    mask = k_pos <= cache_index
    if window is not None:
        mask &= cache_index - k_pos < window
    logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, cv)
    return out.reshape(b, 1, h, hd)


def attention(p: Params, dims: AttnDims, x: jax.Array, positions: jax.Array,
              *, causal: bool = True, kv_cache=None, cache_index=None):
    """Full attention op.  Training/prefill when kv_cache is None; decode
    (x is (B,1,d)) when a cache dict {"k","v"} and fill index are given.

    Returns (out, new_kv_cache_or_None).
    """
    from repro.distributed.sharding import constrain
    b, s, _ = x.shape
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype)),
                  "heads")
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if dims.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, dims.rope_theta)
    k = apply_rope(k, positions, dims.rope_theta)

    new_cache = None
    if kv_cache is not None and s == 1:
        span = kv_cache["k"].shape[1]
        ring = "pos" in kv_cache
        if ring:
            slot = jnp.mod(cache_index, span)
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), slot, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), slot, 1)
            pos = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["pos"], jnp.full((1,), cache_index, jnp.int32),
                slot, 0)
            new_cache = {"k": ck, "v": cv, "pos": pos}
            out = attention_scores(
                q, _expand_kv(ck.astype(q.dtype), dims.n_heads),
                _expand_kv(cv.astype(q.dtype), dims.n_heads),
                causal=True, window=dims.window, q_offset=cache_index,
                k_positions=pos, scale=dims.softmax_scale)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["k"], k.astype(kv_cache["k"].dtype), cache_index, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["v"], v.astype(kv_cache["v"].dtype), cache_index, 1)
            new_cache = {"k": ck, "v": cv}
            out = _grouped_decode_attention(
                q, ck.astype(q.dtype), cv.astype(q.dtype),
                cache_index=cache_index, window=dims.window,
                scale=dims.softmax_scale)
    else:
        # training / single-shot prefill: attend within the chunk, then
        # store the trailing window (or whole chunk) into the cache.
        # Long sequences dispatch to the flash kernel path (Pallas on TPU,
        # chunked custom-VJP ref elsewhere) — O(S*block) live logits.
        from repro.kernels import ops as kops
        out = kops.attention(
            q, constrain(_expand_kv(k, dims.n_heads), "heads"),
            constrain(_expand_kv(v, dims.n_heads), "heads"),
            causal=causal, window=dims.window, q_offset=cache_index or 0,
            scale=dims.softmax_scale)
        out = constrain(out, "heads")
        if kv_cache is not None:
            span = kv_cache["k"].shape[1]
            base = cache_index if cache_index is not None else 0
            if "pos" in kv_cache:   # ring buffer: keep the last `span` keys
                keep = min(s, span)
                idx = jnp.mod(base + s - keep + jnp.arange(keep), span)
                ck = kv_cache["k"].at[:, idx].set(
                    k[:, -keep:].astype(kv_cache["k"].dtype))
                cv = kv_cache["v"].at[:, idx].set(
                    v[:, -keep:].astype(kv_cache["v"].dtype))
                pos = kv_cache["pos"].at[idx].set(
                    (base + s - keep + jnp.arange(keep)).astype(jnp.int32))
                new_cache = {"k": ck, "v": cv, "pos": pos}
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    kv_cache["k"], k.astype(kv_cache["k"].dtype), base, 1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    kv_cache["v"], v.astype(kv_cache["v"].dtype), base, 1)
                new_cache = {"k": ck, "v": cv}
    out = constrain(jnp.einsum("bshk,hkd->bsd", out,
                               p["wo"].astype(x.dtype)), "residual")
    return out, new_cache


def init_kv_cache(batch: int, max_seq: int, dims: AttnDims,
                  dtype=jnp.bfloat16) -> Params:
    """KV cache; sliding-window dims get a ring buffer of `window` slots
    plus an absolute-position array (-1 = unwritten)."""
    span = max_seq if dims.window is None else min(max_seq, dims.window)
    shape = (batch, span, dims.n_kv_heads, dims.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if dims.window is not None and span < max_seq:
        cache["pos"] = jnp.full((span,), -1, jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# Gated MLPs (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    return {
        "wi_gate": truncated_normal(k1, (d_model, d_ff), s_in),
        "wi_up": truncated_normal(k2, (d_model, d_ff), s_in),
        "wo": truncated_normal(k3, (d_ff, d_model), s_out),
    }


def mlp(p: Params, x: jax.Array, activation: str = "silu") -> jax.Array:
    from repro.distributed.sharding import constrain
    act = {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[activation]
    gate = act(constrain(jnp.einsum("bsd,df->bsf", x,
                                    p["wi_gate"].astype(x.dtype)), "hidden"))
    up = constrain(jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype)),
                   "hidden")
    return constrain(jnp.einsum("bsf,fd->bsd", gate * up,
                                p["wo"].astype(x.dtype)), "residual")


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(key, vocab: int, d_model: int, tied: bool = True) -> Params:
    p = {"table": truncated_normal(key, (vocab, d_model), d_model ** -0.5)}
    if not tied:
        p["unembed"] = truncated_normal(
            jax.random.fold_in(key, 1), (d_model, vocab), d_model ** -0.5)
    return p


def embed(p: Params, tokens: jax.Array, scale: float = 1.0,
          dtype=jnp.bfloat16) -> jax.Array:
    x = p["table"].astype(dtype)[tokens]
    return x * jnp.asarray(scale, dtype)


def unembed(p: Params, x: jax.Array, cap: float | None = None) -> jax.Array:
    table = p.get("unembed")
    if table is None:
        logits = jnp.einsum("bsd,vd->bsv", x, p["table"].astype(x.dtype),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, table.astype(x.dtype),
                            preferred_element_type=jnp.float32)
    if cap is not None:
        logits = cap * jnp.tanh(logits / cap)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean token cross-entropy with an optional z-loss regularizer."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = logz - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(logz)
    return jnp.mean(loss)

"""Model zoo: pure-JAX, scan-over-layers LM family implementations."""

"""Serializable inverse-design problems and typed results.

:class:`InverseProblem` is the ``deepnvm.inverse/1`` document: an
embedded sweepspec (``deepnvm.sweepspec/2`` — the scenarios, the corner
grid the relaxation spans, and the platforms) plus the objective, the
area-budget/target formulation, and the solver hyperparameters.  Like
the sweepspec it is strict on unknown fields and round-trips through
JSON unchanged.

:class:`InverseResult` is what the driver returns: the converged leaves
per (flavor, node) group, the relaxed optimum and its standard-path
(non-relaxed engine) re-evaluation with the measured parity, the nearest
grid corner and the grid-argmin reference value, active constraints,
and the per-start loss trajectory.
"""

from __future__ import annotations

import dataclasses
import json
from collections.abc import Mapping

from repro.core import sweep as sweep_mod
from repro.core.cachemodel import CacheDesign

SCHEMA = "deepnvm.inverse/1"

OBJECTIVES = ("edp", "edap")


@dataclasses.dataclass(frozen=True)
class InverseProblem:
    """One inverse-design question, serializable as ``deepnvm.inverse/1``.

    ``sweep`` declares the corner grid the relaxation spans (its design
    points become the softmin corner axis; its NVM (flavor, node) pairs
    become the leaf groups).  ``area_budget_mm2`` is a float budget,
    ``"iso"`` (the max area over the grid corners — the iso-area
    formulation), or None (unconstrained).  ``target`` switches from
    minimization to target-hitting: loss (ln obj - ln target)^2.
    """

    sweep: sweep_mod.SymbolicSweepSpec
    objective: str = "edp"
    include_dram: bool = False
    area_budget_mm2: float | str | None = "iso"
    target: float | None = None
    name: str = "inverse"
    starts: int = 8
    iters: int = 150
    temp_hi: float = 1.0
    temp_lo: float = 1e-2
    lr: float = 0.05
    seed: int = 0

    def __post_init__(self) -> None:
        if self.objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {self.objective!r}; "
                             f"known: {OBJECTIVES}")
        if isinstance(self.area_budget_mm2, str) \
                and self.area_budget_mm2 != "iso":
            raise ValueError("area_budget_mm2 must be a number, 'iso', or "
                             f"null, not {self.area_budget_mm2!r}")
        if self.starts < 1 or self.iters < 1:
            raise ValueError("starts and iters must be >= 1")
        if not 0.0 < self.temp_lo <= self.temp_hi:
            raise ValueError("need 0 < temp_lo <= temp_hi")

    # -- (de)serialization -------------------------------------------------

    def to_doc(self) -> dict:
        doc: dict = {"schema": SCHEMA,
                     "name": self.name,
                     "sweep": self.sweep.to_doc(),
                     "objective": self.objective}
        if self.include_dram:
            doc["include_dram"] = True
        if self.area_budget_mm2 is not None:
            doc["area_budget_mm2"] = self.area_budget_mm2
        if self.target is not None:
            doc["target"] = self.target
        doc.update(starts=self.starts, iters=self.iters,
                   temp_hi=self.temp_hi, temp_lo=self.temp_lo,
                   lr=self.lr, seed=self.seed)
        return doc

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_doc(), indent=indent) + "\n"

    @classmethod
    def from_json(cls, doc: str | Mapping) -> InverseProblem:
        if not isinstance(doc, Mapping):
            doc = json.loads(doc)
        if doc.get("schema") != SCHEMA:
            raise ValueError(f"unsupported problem schema "
                             f"{doc.get('schema')!r} (this build reads "
                             f"{SCHEMA!r})")
        known = {"schema", "name", "sweep", "objective", "include_dram",
                 "area_budget_mm2", "target", "starts", "iters",
                 "temp_hi", "temp_lo", "lr", "seed"}
        unknown = set(doc) - known
        if unknown:
            raise ValueError(f"unknown problem fields {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        if "sweep" not in doc:
            raise ValueError("problem document lacks 'sweep'")
        kwargs = {k: doc[k] for k in known - {"schema", "sweep"} if k in doc}
        return cls(sweep=sweep_mod.SymbolicSweepSpec.from_json(doc["sweep"]),
                   **kwargs)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> InverseProblem:
        with open(path) as f:
            return cls.from_json(f.read())


@dataclasses.dataclass(frozen=True)
class InverseResult:
    """Converged inverse design plus everything needed to audit it."""

    problem: InverseProblem
    leaves: dict[tuple[str, str], dict[str, float]]  # (flavor, node) -> leaf
    objective: str
    best_value: float            # relaxed optimum (hardened selection)
    standard_value: float        # same point through the standard engine
    parity_rel_err: float        # |best - standard| / standard
    grid_best_value: float       # grid-argmin reference (anchor leaves)
    corner: dict                 # winning (mem, capacity_mb, node, org)
    design: CacheDesign          # standard-path design at the optimum
    area_mm2: float
    area_budget_mm2: float | None
    trajectory: tuple[float, ...]       # best start's per-iter loss
    start_losses: tuple[float, ...]     # final loss per start
    converged_start: int
    iterations: int
    n_starts: int
    active_constraints: dict[str, object]

    @property
    def gain_vs_grid(self) -> float:
        """Fractional objective improvement over the grid argmin."""
        return 1.0 - self.best_value / self.grid_best_value

    def to_doc(self) -> dict:
        return {
            "schema": "deepnvm.inverse_result/1",
            "problem": self.problem.to_doc(),
            "leaves": {"/".join(k): v for k, v in self.leaves.items()},
            "objective": self.objective,
            "best_value": self.best_value,
            "standard_value": self.standard_value,
            "parity_rel_err": self.parity_rel_err,
            "grid_best_value": self.grid_best_value,
            "gain_vs_grid": self.gain_vs_grid,
            "corner": self.corner,
            "area_mm2": self.area_mm2,
            "area_budget_mm2": self.area_budget_mm2,
            "active_constraints": self.active_constraints,
            "converged_start": self.converged_start,
            "iterations": self.iterations,
            "n_starts": self.n_starts,
            "final_losses": list(self.start_losses),
        }

    def summary(self) -> str:
        lines = [
            f"inverse {self.problem.name}: objective={self.objective}",
            f"  best (relaxed, hardened): {self.best_value:.6e}",
            f"  standard-path re-eval:    {self.standard_value:.6e}"
            f"  (parity {self.parity_rel_err:.2e})",
            f"  grid argmin reference:    {self.grid_best_value:.6e}"
            f"  (gain {100.0 * self.gain_vs_grid:+.2f}%)",
            f"  corner: {self.corner}",
            f"  area: {self.area_mm2:.3f} mm^2"
            + (f" (budget {self.area_budget_mm2:.3f})"
               if self.area_budget_mm2 is not None else ""),
            f"  starts: {self.n_starts} x {self.iterations} iters, "
            f"winner #{self.converged_start}",
        ]
        for key, leaves in self.leaves.items():
            lines.append(f"  leaves {'/'.join(key)}:")
            for f, v in leaves.items():
                lines.append(f"    {f} = {v:.6g}")
        if self.active_constraints:
            lines.append(f"  active constraints: {self.active_constraints}")
        return "\n".join(lines)

"""The differentiable lowering: device leaves -> soft bitcells -> PPA ->
workload fold -> softmin-selected objective.

This is the unmemoized, non-argmin variant of the standard pipeline.
Three discrete choices become temperature-annealed softmin relaxations:

* the **fin assignment** of each NVM bitcell (the ``bitcell.
  fin_assignments`` grid): every assignment's 7-vector is evaluated with
  the *same scalar operation order* as ``bitcell._evaluate`` (at a hard
  temperature the mixture weights are exactly one-hot, so the cell
  matches the winning assignment's vector to the few ulps the
  ``exp(ln(anchor))`` theta round-trip introduces), infeasible
  assignments (write current below Ic0) are masked with -inf logits,
  and the mixture weights are a softmin over the bitcell EDAP;
* the **(mem, capacity, node) corner x organization** selection: one
  ``engine.ppa_fn`` call over the unique node/mem/capacity cross
  product (the same compiled kernel the memoized path dispatches — a
  traced cell matrix composes with ``jax.grad`` through the jit), the
  per-corner tensors are gathered by static index arrays, the workload
  objective folds through ``workload_engine._fold``, and a joint
  softmin over all valid (corner, org) cells yields the relaxed
  objective and area;
* the **STT scaling wall**: instead of ``characterize``'s raised
  diagnostic, the best overdrive across assignments enters the loss as
  a softplus penalty, so the optimizer feels the wall as a smooth
  gradient (and the extrapolated 2 nm node is a finite, differentiable
  point instead of an exception).

Everything discrete about the problem (the spec axes, the assignment
grids, the validity masks, platform/stream tensors) is precomputed as
numpy constants at lowering time; the traced functions are pure maps
from ``theta = ln(leaves)`` (and a temperature) to scalars, so the
driver can ``jit``/``vmap``/``grad`` them freely.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import bitcell as bitcell_mod
from repro.core import calibration, engine, workload_engine
from repro.core.bitcell import (
    _AREA_PER_FIN,
    _I_READ_PER_FIN,
    _STT_READ_CAP_FRAC,
    _bitcell_scale,
)
from repro.core.sweep import DesignPoint
from repro.core.tech import TechNode
from repro.inverse import bounds
from repro.inverse.bounds import LeafGroup, N_LEAVES
from repro.inverse.problem import InverseProblem

# Temperature at which the softmins are exactly one-hot in float64 (the
# smallest log-metric gaps in this model are ~1e-2; 1e-2 / 1e-4 = 100
# nats underflows the runner-up weight to exactly 0.0).
HARD_TEMP = 1e-4

# Overdrive scale of the scaling-wall softplus penalty: the wall "turns
# on" within ~0.05 of zero overdrive.
WALL_SCALE = 0.05
LAMBDA_WALL = 10.0
# Area-budget hinge: softplus((soft_area/budget - 1) / SIGMA) — stiff
# within ~1% of the budget.
SIGMA_AREA = 0.01
LAMBDA_AREA = 50.0

# Overdrive clamp for masked (infeasible) assignments: keeps the masked
# branch finite (inf * 0 would poison the softmin mixture's gradients)
# without perturbing any feasible overdrive the sweep would accept.
_OD_FLOOR = 1e-30


@dataclasses.dataclass(frozen=True)
class _Assignment:
    """Static per-fin-assignment constants (scalar op order preserved)."""

    fins_read: int
    fins_write: int
    shared: bool
    i_write_a: float       # bitcell._write_current(node, fins_write)
    i_read_raw_a: float    # read current before the STT disturb cap
    fin_area_norm: float   # the fins' footprint term
    cell_leak_w: float


def _assignments(flavor: str, node: TechNode) -> tuple[_Assignment, ...]:
    out = []
    for fr, fw, shared in bitcell_mod.fin_assignments(flavor):
        total_fins = fw if shared else fr + fw
        out.append(_Assignment(
            fins_read=fr, fins_write=fw, shared=shared,
            i_write_a=bitcell_mod._write_current(node, fw),
            i_read_raw_a=fr * _I_READ_PER_FIN[flavor]
            * _bitcell_scale("i_read_per_fin", node),
            fin_area_norm=_AREA_PER_FIN
            * _bitcell_scale("area_per_fin", node) * total_fins,
            cell_leak_w=total_fins * node.ioff_per_fin_a * node.vdd_v,
        ))
    return tuple(out)


def soft_cell(theta_g, group: LeafGroup, temp):
    """Softmin fin-assignment mixture of one NVM (flavor, node) group.

    ``theta_g`` is the group's ln-leaf slice.  Returns (cell [7] in
    bitcell.ARRAY_FIELDS order, best overdrive across assignments —
    the scaling-wall signal, > 0 iff some assignment is feasible).

    Every per-assignment expression mirrors ``bitcell._evaluate`` /
    ``mtj.switching_time`` / ``mtj.switching_energy`` operation order;
    at :data:`HARD_TEMP` the mixture weights are exactly one-hot, so
    the cell equals the winning assignment's ``Bitcell.as_array()`` up
    to the few ulps of the ``exp(ln(anchor))`` theta round-trip.
    """
    (ic0_set_a, ic0_reset_a, tau_set_s, tau_reset_s, r_set_ohm,
     r_reset_ohm, sense_time_s, area_base) = (
        jnp.exp(theta_g[i]) for i in range(N_LEAVES))
    node = group.node
    vecs, edaps, od_mins = [], [], []
    for a in _assignments(group.flavor, node):
        od_set = a.i_write_a / ic0_set_a - 1.0
        od_reset = a.i_write_a / ic0_reset_a - 1.0
        od_min = jnp.minimum(od_set, od_reset)
        t_set_s = tau_set_s / jnp.maximum(od_set, _OD_FLOOR)
        t_reset_s = tau_reset_s / jnp.maximum(od_reset, _OD_FLOOR)
        if group.flavor == "stt":
            i_read_a = jnp.minimum(a.i_read_raw_a,
                                   _STT_READ_CAP_FRAC * ic0_set_a)
        else:
            i_read_a = jnp.asarray(a.i_read_raw_a, dtype=jnp.float64)
        sense_e_j = node.vdd_v * i_read_a * sense_time_s
        e_set_j = a.i_write_a * a.i_write_a * r_set_ohm * t_set_s
        e_reset_j = a.i_write_a * a.i_write_a * r_reset_ohm * t_reset_s
        wlat_avg_s = 0.5 * (t_set_s + t_reset_s)
        we_avg_j = 0.5 * (e_set_j + e_reset_j)
        area_norm = area_base + a.fin_area_norm
        vecs.append(jnp.stack([
            i_read_a, sense_time_s, sense_e_j, wlat_avg_s, we_avg_j,
            area_norm, jnp.asarray(a.cell_leak_w, dtype=jnp.float64)]))
        edaps.append((sense_time_s * sense_e_j + wlat_avg_s * we_avg_j)
                     * area_norm)
        od_mins.append(od_min)
    edap = jnp.stack(edaps)
    od_best = jnp.stack(od_mins).max()
    logits = jnp.where(jnp.stack(od_mins) > 0.0,
                       -jnp.log(edap) / temp, -jnp.inf)
    w = jax.nn.softmax(logits)
    cell = (w[:, None] * jnp.stack(vecs)).sum(axis=0)
    return cell, od_best


def _iso_budget(areas_mm2: np.ndarray) -> float:
    """The "iso" area budget: the largest grid-corner area — every grid
    corner is admissible, and the optimum is compared at equal area."""
    return float(np.max(areas_mm2))


@dataclasses.dataclass(frozen=True, eq=False)
class Lowered:
    """A problem lowered to pure traced functions of theta.

    Static structure (axes, index maps, stream/platform tensors, leaf
    groups and bounds) is precomputed; :meth:`loss`, :meth:`metrics`,
    and :meth:`scenario_objective` are pure jnp maps suitable for
    ``jit``/``grad``/``vmap``.  Build via :func:`lower`.
    """

    problem: InverseProblem
    points: tuple[DesignPoint, ...]
    groups: tuple[LeafGroup, ...]
    theta0: np.ndarray           # centers, ln space
    theta_lo: np.ndarray
    theta_hi: np.ndarray
    area_budget_mm2: float | None
    # unique-axis structure
    nodes: tuple[TechNode, ...]
    mems: tuple[str, ...]
    caps: tuple[int, ...]
    nk: np.ndarray               # [k] node index per point
    mk: np.ndarray               # [k] mem index
    ck: np.ndarray               # [k] capacity index
    # kernel constants
    cal_mat: np.ndarray          # [n, m, 8]
    is_sram: np.ndarray          # [m]
    node4: np.ndarray            # [n, 4]
    peri: np.ndarray             # [n, 7]
    caps_arr: np.ndarray         # [c] int64
    const_cells: dict            # (ni, mi) -> [7] np row (non-relaxed)
    relaxed: dict                # (ni, mi) -> group index
    valid: np.ndarray            # [k, o] bool
    caps_k: np.ndarray           # [k] float64 capacity per point
    # fold constants ("edp" objective)
    batch: workload_engine.StreamBatch | None
    pmat: np.ndarray | None

    # -- traced pipeline ---------------------------------------------------

    def _cell_mat(self, theta, temp):
        """[n, m, 7] cell matrix: soft NVM rows, constant sram rows; also
        the per-group best overdrives (the scaling-wall signals)."""
        cells = {}
        od_bests = [None] * len(self.groups)
        for (ni, mi), gi in self.relaxed.items():
            g = self.groups[gi]
            sl = theta[g.offset:g.offset + N_LEAVES]
            cell, od_best = soft_cell(sl, g, temp)
            cells[(ni, mi)] = cell
            od_bests[gi] = od_best
        rows = [jnp.stack([
            cells[(ni, mi)] if (ni, mi) in cells
            else jnp.asarray(self.const_cells[(ni, mi)])
            for mi in range(len(self.mems))])
            for ni in range(len(self.nodes))]
        return jnp.stack(rows), od_bests

    def _ppa(self, theta, temp):
        """Gathered per-point PPA: (rl, wl, re, we) [k, o], leak/area [k],
        plus the per-group overdrives."""
        cell_mat, od_bests = self._cell_mat(theta, temp)
        out = engine.ppa_fn(cell_mat, self.cal_mat, self.is_sram,
                            self.node4, self.peri, self.caps_arr,
                            engine.ORG_BANKS, engine.ORG_ROWS,
                            engine.ORG_COLS, engine.ORG_ACCESS,
                            anchor_peri=False)
        nk, mk, ck = self.nk, self.mk, self.ck
        return (out["read_latency_s"][nk, mk, ck],
                out["write_latency_s"][nk, mk, ck],
                out["read_energy_j"][nk, mk, ck],
                out["write_energy_j"][nk, mk, ck],
                out["leakage_w"][nk, mk, ck],
                out["area_mm2"][nk, mk, ck],
                od_bests)

    def _fold_edp(self, rl, wl, re_, we_, leak):
        """[p, s, k, o] EDP through the workload fold (the scalar
        WorkloadTable.edp operation order)."""
        k, o = rl.shape
        b = self.batch
        # eager (numpy-backed) calls warn on the rd=inf streams' inf/inf
        # before the fold's where() masks them; the jitted path is silent
        with np.errstate(invalid="ignore"):
            out = workload_engine._fold(
                b.bytes_total, b.is_write, b.reuse_distance,
                b.dram_visible, b.mask, b.macs,
                rl.reshape(-1), wl.reshape(-1), re_.reshape(-1),
                we_.reshape(-1), jnp.repeat(leak, o),
                np.repeat(self.caps_k, o), self.pmat)
        total = out["dyn_read_j"][None] + out["dyn_write_j"][None] \
            + out["leak_j"]
        if self.problem.include_dram:
            total = total + out["dram_j"]
        edp = total * out["runtime_s"]                     # [p, s, k*o]
        return edp.reshape(edp.shape[0], edp.shape[1], k, o)

    def _objective(self, rl, wl, re_, we_, leak, area):
        """[k, o] objective tensor from gathered PPA quantities.  Shared
        by the relaxed path and :meth:`grid_objective`, so softmin ->
        argmin recovery is consistent by construction."""
        if self.problem.objective == "edap":
            e = 0.5 * (re_ + we_)
            d = 0.5 * (rl + wl)
            return e * d * area[:, None]
        edp = self._fold_edp(rl, wl, re_, we_, leak)
        return edp.mean(axis=(0, 1))

    def objective_matrix(self, theta, temp=HARD_TEMP):
        """([k, o] objective, [k] area, per-group overdrives) at the
        given fin-mixture temperature."""
        rl, wl, re_, we_, leak, area, od_bests = self._ppa(theta, temp)
        return self._objective(rl, wl, re_, we_, leak, area), area, od_bests

    def loss(self, theta, temp):
        """The annealed scalar loss: softmin objective + area hinge +
        scaling-wall penalty (target mode squares the log residual)."""
        obj, area, od_bests = self.objective_matrix(theta, temp)
        obj_safe = jnp.where(self.valid, obj, 1.0)
        logits = jnp.where(self.valid, -jnp.log(obj_safe) / temp,
                           -jnp.inf).reshape(-1)
        w = jax.nn.softmax(logits).reshape(obj.shape)
        soft_obj = (w * obj_safe).sum()
        soft_area = (w.sum(axis=1) * area).sum()
        if self.problem.target is not None:
            out = (jnp.log(soft_obj)
                   - math.log(self.problem.target)) ** 2
        else:
            out = jnp.log(soft_obj)
        if self.area_budget_mm2 is not None:
            out = out + LAMBDA_AREA * jax.nn.softplus(
                (soft_area / self.area_budget_mm2 - 1.0) / SIGMA_AREA)
        for od_best in od_bests:
            out = out + LAMBDA_WALL * jax.nn.softplus(-od_best / WALL_SCALE)
        return out

    def wall_penalty(self, theta):
        """The scaling-wall penalty alone (diagnostic; ~0 when every
        group has overdrive headroom, large past the wall)."""
        _, od_bests = self._cell_mat(theta, HARD_TEMP)
        pen = 0.0
        for od_best in od_bests:
            pen = pen + LAMBDA_WALL * jax.nn.softplus(-od_best / WALL_SCALE)
        return pen

    def scenario_objective(self, theta, org_idx: tuple[int, ...]):
        """ln objective per (platform, scenario) at fixed per-point orgs
        — the sensitivity layer's map ([p, s, k]; "edap" has no scenario
        axis and returns ln EDAP [1, 1, k])."""
        rl, wl, re_, we_, leak, area, _ = self._ppa(theta, HARD_TEMP)
        oi = np.asarray(org_idx)
        kk = np.arange(len(self.points))
        if self.problem.objective == "edap":
            e = 0.5 * (re_[kk, oi] + we_[kk, oi])
            d = 0.5 * (rl[kk, oi] + wl[kk, oi])
            return jnp.log(e * d * area)[None, None, :]
        edp = self._fold_edp(rl[kk, oi][:, None], wl[kk, oi][:, None],
                             re_[kk, oi][:, None], we_[kk, oi][:, None],
                             leak)
        return jnp.log(edp[..., 0])

    # -- hardened / reference evaluations ----------------------------------

    def masked_argmin(self, obj: np.ndarray, area: np.ndarray,
                      ) -> tuple[int, int]:
        """(point, org) argmin over valid cells within the area budget."""
        mask = np.array(self.valid)
        if self.area_budget_mm2 is not None:
            mask = mask & (np.asarray(area)[:, None]
                           <= self.area_budget_mm2 * (1.0 + 1e-9))
        if not mask.any():
            raise ValueError("no (corner, org) cell satisfies the area "
                             f"budget {self.area_budget_mm2} mm^2")
        flat = int(np.argmin(np.where(mask, np.asarray(obj), np.inf)))
        return flat // engine.N_ORGS, flat % engine.N_ORGS

    def grid_objective(self) -> tuple[np.ndarray, np.ndarray]:
        """([k, o] objective, [k] area) through the standard memoized
        engine path (``engine.design_table``) with anchor leaves — the
        grid-argmin reference the relaxation is checked against."""
        table = engine.design_table(self.mems, self.caps, nodes=self.nodes)
        nk, mk, ck = self.nk, self.mk, self.ck
        obj = self._objective(
            table.read_latency_s[nk, mk, ck],
            table.write_latency_s[nk, mk, ck],
            table.read_energy_j[nk, mk, ck],
            table.write_energy_j[nk, mk, ck],
            table.leakage_w[nk, mk, ck],
            table.area_mm2[nk, mk, ck])
        return np.asarray(obj), np.asarray(table.area_mm2[nk, mk, ck])

    def corner_info(self, ki: int, oi: int) -> dict:
        """Human-readable identity of one (point, org) cell."""
        p = self.points[ki]
        org = engine.ORGS[oi]
        return {"mem": p.mem, "capacity_mb": p.capacity_mb,
                "node": p.node.name, "org_index": oi,
                "org": f"{org.banks}b x {org.rows}r x {org.cols}c "
                       f"x {org.access}"}


def lower(problem: InverseProblem) -> Lowered:
    """Lower a problem to its static structure + traced functions."""
    spec = problem.sweep.resolve()
    points = spec.designs
    groups = bounds.leaf_groups(points)
    if not groups:
        raise ValueError(f"{problem.name}: no NVM design points — nothing "
                         "to optimize (every leaf is an MRAM device knob)")
    theta0 = bounds.pack_theta(groups)
    theta_lo, theta_hi = bounds.theta_bounds(groups)

    nodes = tuple(dict.fromkeys(p.node for p in points))
    mems = tuple(dict.fromkeys(p.mem for p in points))
    caps = tuple(dict.fromkeys(p.capacity_bytes for p in points))
    nk = np.array([nodes.index(p.node) for p in points])
    mk = np.array([mems.index(p.mem) for p in points])
    ck = np.array([caps.index(p.capacity_bytes) for p in points])

    group_index = {g.key: i for i, g in enumerate(groups)}
    const_cells, relaxed = {}, {}
    for ni, nd in enumerate(nodes):
        for mi, mem in enumerate(mems):
            key = (mem, nd.name)
            if key in group_index:
                relaxed[(ni, mi)] = group_index[key]
            elif mem == "sram":
                const_cells[(ni, mi)] = \
                    bitcell_mod.characterize(mem, nd).as_array()
            else:
                # an (NVM, node) combo no design point uses: the kernel
                # still wants a row; its outputs are never gathered
                const_cells[(ni, mi)] = np.ones(
                    len(bitcell_mod.ARRAY_FIELDS))
    cal_mat = np.array([[[getattr(calibration.get(m, nd), f)
                          for f in engine.CAL_FIELDS]
                         for m in mems] for nd in nodes])
    is_sram = np.array([m == "sram" for m in mems])
    node_mat = np.stack([engine.node_row(nd) for nd in nodes])
    n_technode = len(engine.TECHNODE_FIELDS)
    caps_arr = np.array(caps, dtype=np.int64)

    if problem.objective == "edp":
        stats = spec.scenarios
        batch = workload_engine.pack(stats)
        pmat = np.stack([np.array([getattr(p, f)
                                   for f in workload_engine.PLATFORM_FIELDS])
                         for p in spec.platforms])
    else:
        batch, pmat = None, None

    lowered = Lowered(
        problem=problem, points=points, groups=groups,
        theta0=theta0, theta_lo=theta_lo, theta_hi=theta_hi,
        area_budget_mm2=None,
        nodes=nodes, mems=mems, caps=caps, nk=nk, mk=mk, ck=ck,
        cal_mat=cal_mat, is_sram=is_sram,
        node4=np.ascontiguousarray(node_mat[:, :n_technode]),
        peri=np.ascontiguousarray(node_mat[:, n_technode:]),
        caps_arr=caps_arr, const_cells=const_cells, relaxed=relaxed,
        valid=engine.valid_mask(caps_arr)[ck],
        caps_k=np.array([float(p.capacity_bytes) for p in points]),
        batch=batch, pmat=pmat)

    budget = problem.area_budget_mm2
    if budget == "iso":
        with enable_x64():
            _, grid_areas = lowered.grid_objective()
        budget = _iso_budget(grid_areas)
    if budget is not None:
        budget = float(budget)
    return dataclasses.replace(lowered, area_budget_mm2=budget)

"""Elasticity tables: d ln(metric) / d ln(leaf) per (node, tech, scenario).

The sensitivity layer answers the paper-level question "which device
knob buys the most EDP at each node" with one forward-mode Jacobian of
the relaxed pipeline.  Because theta is ln(leaf) space and the map is
``Lowered.scenario_objective`` (ln objective at fixed per-point winner
orgs), the raw Jacobian entries *are* elasticities: a value of -0.7 for
``tau_set_s`` means a 1% faster set pulse buys 0.7% EDP at that
(node, tech, scenario) — directly comparable across leaves of wildly
different units and magnitudes.

Orgs are pinned at each design point's own grid-argmin winner (the
organization Algorithm 1 would pick), so the tables describe the
sensitivity of *tuned* designs, not of an arbitrary organization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro import scenarios as scenarios_mod
from repro.inverse import relax
from repro.inverse.bounds import LEAF_FIELDS, N_LEAVES
from repro.inverse.problem import InverseProblem
from repro.inverse.relax import Lowered


def winner_orgs(lowered: Lowered) -> tuple[int, ...]:
    """Each design point's grid-argmin organization index (the org the
    standard tuned sweep would select for that corner)."""
    obj, _ = lowered.grid_objective()
    masked = np.where(np.asarray(lowered.valid), obj, np.inf)
    return tuple(int(i) for i in np.argmin(masked, axis=1))


def sensitivity_rows(problem: InverseProblem,
                     lowered: Lowered | None = None,
                     theta: np.ndarray | None = None) -> list[dict]:
    """Flat elasticity table at ``theta`` (default: the anchor centers).

    One row per (platform, scenario, NVM design point, leaf):
    ``{"node", "mem", "capacity_mb", "platform", "scenario", "leaf",
    "elasticity", "center"}`` where ``elasticity`` is
    d ln(objective) / d ln(leaf).  For the "edap" objective the
    platform/scenario columns are None (EDAP has no workload axis).
    """
    with enable_x64():
        lowered = lowered if lowered is not None else relax.lower(problem)
        theta = lowered.theta0 if theta is None else np.asarray(theta)
        org_idx = winner_orgs(lowered)
        jac_fn = jax.jit(jax.jacfwd(
            lambda th: lowered.scenario_objective(th, org_idx)))
        jac = np.asarray(jac_fn(jnp.asarray(theta)))     # [p, s, k, T]

        spec = problem.sweep.resolve()
        if problem.objective == "edap":
            plat_names: tuple[str | None, ...] = (None,)
            scen_names: tuple[str | None, ...] = (None,)
        else:
            plat_names = tuple(p.name for p in spec.platforms)
            scen_names = tuple(scenarios_mod.name_of(s)
                               for s in spec.scenarios)

        rows = []
        for ki, point in enumerate(lowered.points):
            key = (int(lowered.nk[ki]), int(lowered.mk[ki]))
            if key not in lowered.relaxed:
                continue                   # sram corner: no leaves
            g = lowered.groups[lowered.relaxed[key]]
            for pi, plat in enumerate(plat_names):
                for si, scen in enumerate(scen_names):
                    for li, leaf in enumerate(LEAF_FIELDS):
                        rows.append({
                            "node": point.node.name,
                            "mem": point.mem,
                            "capacity_mb": point.capacity_mb,
                            "platform": plat,
                            "scenario": scen,
                            "leaf": leaf,
                            "elasticity": float(
                                jac[pi, si, ki, g.offset + li]),
                            "center": g.centers[li],
                        })
        return rows


def top_knobs(rows: list[dict], n: int = 1) -> list[dict]:
    """The ``n`` largest |elasticity| leaves per (node, mem), averaged
    over platforms and scenarios — the headline "which knob buys the
    most" ranking."""
    acc: dict[tuple[str, str, str], list[float]] = {}
    centers: dict[tuple[str, str, str], float] = {}
    for r in rows:
        key = (r["node"], r["mem"], r["leaf"])
        acc.setdefault(key, []).append(r["elasticity"])
        centers[key] = r["center"]
    out = []
    by_design: dict[tuple[str, str], list[tuple[str, float]]] = {}
    for (node, mem, leaf), vals in acc.items():
        by_design.setdefault((node, mem), []).append(
            (leaf, float(np.mean(vals))))
    for (node, mem), leaves in sorted(by_design.items()):
        for leaf, mean_el in sorted(leaves,
                                    key=lambda t: -abs(t[1]))[:n]:
            out.append({"node": node, "mem": mem, "leaf": leaf,
                        "mean_elasticity": mean_el,
                        "center": centers[(node, mem, leaf)]})
    return out


__all__ = ["sensitivity_rows", "top_knobs", "winner_orgs", "LEAF_FIELDS",
           "N_LEAVES"]

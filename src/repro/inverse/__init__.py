"""Gradient-based inverse design over the DeepNVM++ PPA model.

The reproduction's engine is one pure jitted float64 JAX function from
device constants to EDP, so questions the paper only grid-argmins —
"which device knob buys the most EDP at 7 nm?", "what pulse width and
cell footprint hit a target EDP under an area budget?" — become
gradient problems:

* :mod:`repro.inverse.bounds` — the continuous *leaves*: per (flavor,
  node) device anchors (Ic0, switching time constants, write-path
  resistances, sense window) plus the fin-independent bitcell footprint,
  each bounded multiplicatively around its node-projected center using
  the documented scaling-exponent tables.
* :mod:`repro.inverse.relax` — the differentiable lowering: an
  unmemoized, non-argmin variant of device -> bitcell -> periphery ->
  PPA -> workload-fold where the discrete choices (fin assignments, the
  (mem, capacity, node) corner, the 288-org grid) are temperature-
  annealed softmin mixtures, the STT scaling wall is a differentiable
  penalty, and the PPA equations are the *same* compiled kernel
  (``engine.ppa_fn``) the memoized sweep path dispatches.
* :mod:`repro.inverse.driver` — batched multi-start projected Adam
  (``vmap`` over starts) solving ``minimize EDP s.t. area <= budget``
  and target-hitting formulations, plus the standard-path re-evaluation
  (``mtj.custom_device`` + ``bitcell.assemble`` + ``engine.evaluate``)
  that verifies every converged point at <= 1e-12 parity.
* :mod:`repro.inverse.problem` — the serializable ``deepnvm.inverse/1``
  problem document (an embedded sweepspec plus objective/budget/solver
  fields) and the typed :class:`InverseResult`.
* :mod:`repro.inverse.sensitivity` — d(metric)/d(param) elasticity
  tables per (node, tech, scenario), ranking which device knob buys the
  most EDP at each node (benchmarks/fig_sensitivity.py).
"""

from repro.inverse.bounds import LEAF_FIELDS, LeafGroup, leaf_groups
from repro.inverse.driver import grid_argmin, recover_corner, solve, verify
from repro.inverse.problem import SCHEMA, InverseProblem, InverseResult
from repro.inverse.relax import Lowered, lower
from repro.inverse.sensitivity import sensitivity_rows

__all__ = [
    "LEAF_FIELDS", "LeafGroup", "leaf_groups",
    "grid_argmin", "recover_corner", "solve", "verify",
    "SCHEMA", "InverseProblem", "InverseResult",
    "Lowered", "lower",
    "sensitivity_rows",
]

"""Continuous leaves and node-aware bounds of the inverse problem.

A *leaf* is one device/bitcell anchor the optimizer may move: the MTJ
compact-model constants that actually enter the PPA equations (Ic0 per
polarity, the precessional time constants, the write-path resistances,
the sense window) plus the fin-independent bitcell footprint term.  The
read-path resistance is deliberately **not** a leaf — it never enters a
PPA expression (sensing is current-mode in this model), so its gradient
is identically zero and exposing it would only produce dead axes.

Leaves live per (flavor, node) *group*: each NVM technology at each
technology node of the problem's design axis gets its own copy, centered
on the node-projected anchor (``mtj.device`` / ``bitcell.base_area_norm``
— exactly the values the standard characterization path uses, so a
center evaluation reproduces the grid model).  Bounds are multiplicative
spans around the center derived from the documented scaling-exponent
tables: a knob whose 16 -> 7 nm projection moves by ``s**e`` is allowed
at least that much headroom in either direction (floored at 2x), i.e.
the optimizer may trade a knob across the whole validated projection
range but not into fantasy-device territory.

The optimizer works in theta = ln(leaf) space (multiplicative moves,
scale-free gradients); :func:`pack_theta` / :func:`theta_bounds` build
the flat vectors, and each group knows its slice of theta.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import bitcell, mtj, tech
from repro.core.tech import TechNode, TECH_16NM, MIN_FEATURE_SIZE_M

# The exposed leaves, in theta packing order.  The first seven are
# MTJDevice fields; area_base_norm is the bitcell footprint term.
LEAF_FIELDS = (
    "ic0_set_a",
    "ic0_reset_a",
    "tau_set_s",
    "tau_reset_s",
    "r_set_ohm",
    "r_reset_ohm",
    "sense_time_s",
    "area_base_norm",
)
DEVICE_LEAVES = LEAF_FIELDS[:-1]
N_LEAVES = len(LEAF_FIELDS)

# Multiplicative half-span floor: every leaf may at least halve/double.
_SPAN_FLOOR = 2.0
# The validated projection range end-to-end: 16 nm anchor to the 7 nm
# MIN_FEATURE_SIZE_M wall.
_RANGE_RATIO = TECH_16NM.feature_size_m / MIN_FEATURE_SIZE_M


def leaf_span(flavor: str, field: str) -> float:
    """Multiplicative half-span of one leaf: how far the documented node
    scaling (``s**e`` across the full validated 16 -> 7 nm range) moves
    it, floored at :data:`_SPAN_FLOOR`."""
    if field == "area_base_norm":
        e = tech.BITCELL_SCALING_EXPONENTS["area_base"]
    else:
        e = tech.MTJ_SCALING_EXPONENTS[flavor][field]
    return max(_SPAN_FLOOR, _RANGE_RATIO ** abs(e))


def leaf_centers(flavor: str, node: TechNode) -> dict[str, float]:
    """Node-projected anchor value of every leaf — the values the
    standard characterization path (``mtj.device`` + ``bitcell``) uses,
    so theta at the centers reproduces the grid model exactly."""
    dev = mtj.device(flavor, node)
    centers = {f: getattr(dev, f) for f in DEVICE_LEAVES}
    centers["area_base_norm"] = bitcell.base_area_norm(flavor, node)
    return centers


@dataclasses.dataclass(frozen=True)
class LeafGroup:
    """One (flavor, node) copy of the leaves with centers and bounds.

    ``offset`` is the group's position in the flat theta vector: its
    leaves occupy ``theta[offset : offset + N_LEAVES]`` in LEAF_FIELDS
    order.
    """

    flavor: str
    node: TechNode
    offset: int
    centers: tuple[float, ...]   # [N_LEAVES] anchor values
    lo: tuple[float, ...]        # [N_LEAVES] lower bounds
    hi: tuple[float, ...]        # [N_LEAVES] upper bounds

    @property
    def key(self) -> tuple[str, str]:
        return (self.flavor, self.node.name)

    def leaves(self, theta: np.ndarray) -> dict[str, float]:
        """This group's leaf values out of a flat theta vector."""
        vals = np.exp(np.asarray(theta)[self.offset:self.offset + N_LEAVES])
        return dict(zip(LEAF_FIELDS, (float(v) for v in vals)))

    def device_overrides(self, theta: np.ndarray) -> dict[str, float]:
        """The MTJDevice fields of :meth:`leaves` — the kwargs of
        ``mtj.custom_device``."""
        leaves = self.leaves(theta)
        return {f: leaves[f] for f in DEVICE_LEAVES}

    def at_bound(self, theta: np.ndarray, rel_tol: float = 1e-6,
                 ) -> dict[str, str]:
        """Leaves pinned at a bound (active box constraints): leaf name
        -> "lo" / "hi"."""
        out = {}
        for i, f in enumerate(LEAF_FIELDS):
            v = math.exp(float(theta[self.offset + i]))
            if v <= self.lo[i] * (1.0 + rel_tol):
                out[f] = "lo"
            elif v >= self.hi[i] * (1.0 - rel_tol):
                out[f] = "hi"
        return out


def leaf_groups(points) -> tuple[LeafGroup, ...]:
    """One :class:`LeafGroup` per distinct NVM (flavor, node) pair of the
    design points (``(mem, capacity_bytes, node)`` triples or objects
    with ``.mem``/``.node``), in first-appearance order."""
    seen: dict[tuple[str, str], tuple[str, TechNode]] = {}
    for p in points:
        mem, node = (p[0], p[2]) if isinstance(p, tuple) else (p.mem, p.node)
        if mem != "sram" and (mem, node.name) not in seen:
            seen[(mem, node.name)] = (mem, node)
    groups = []
    for offset_idx, (flavor, node) in enumerate(seen.values()):
        centers = leaf_centers(flavor, node)
        lo, hi = [], []
        for f in LEAF_FIELDS:
            span = leaf_span(flavor, f)
            lo.append(centers[f] / span)
            hi.append(centers[f] * span)
        groups.append(LeafGroup(
            flavor=flavor, node=node, offset=offset_idx * N_LEAVES,
            centers=tuple(centers[f] for f in LEAF_FIELDS),
            lo=tuple(lo), hi=tuple(hi)))
    return tuple(groups)


def pack_theta(groups: tuple[LeafGroup, ...]) -> np.ndarray:
    """theta at the centers: ln of every group's anchor values."""
    return np.log(np.concatenate(
        [np.asarray(g.centers, dtype=np.float64) for g in groups]))


def theta_bounds(groups: tuple[LeafGroup, ...],
                 ) -> tuple[np.ndarray, np.ndarray]:
    """(lo, hi) of the theta box, in ln space."""
    lo = np.log(np.concatenate(
        [np.asarray(g.lo, dtype=np.float64) for g in groups]))
    hi = np.log(np.concatenate(
        [np.asarray(g.hi, dtype=np.float64) for g in groups]))
    return lo, hi

"""Multi-start projected-Adam driver + standard-path verification.

``solve`` runs batched gradient descent on a :class:`~repro.inverse.
relax.Lowered` problem: starts are a [S, T] theta batch (start 0 at the
anchor centers, the rest uniform in the ln-bounds box), each start runs
``iters`` projected-Adam steps under a geometric temperature schedule
(one ``lax.scan``, temperatures as the scan xs), and the whole batch is
``jax.vmap``-ed and jitted — wide start grids evaluate as one batched
computation, chunked like the sharded sweep lowering so an S=512 grid
does not materialize at once.

Hardening is explicit, not asymptotic: every converged start is
re-evaluated at :data:`~repro.inverse.relax.HARD_TEMP` (where the
softmins are exactly one-hot), the winning (corner, org) cell is an
argmin over the hardened objective matrix restricted to the area
budget, and ``verify`` re-builds that exact design through the
*standard* non-relaxed path — ``mtj.custom_device`` ->
``bitcell.assemble`` -> ``engine.evaluate`` ->
``workload_engine.evaluate_platforms`` — and reports the measured
relative parity.  The result therefore never rests on the relaxation:
every number in an :class:`InverseResult` is backed by the same code
path the paper-reproduction sweeps use.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core import bitcell as bitcell_mod
from repro.core import calibration, engine, mtj, workload_engine
from repro.core.cachemodel import CacheDesign
from repro.inverse import relax
from repro.inverse.problem import InverseProblem, InverseResult
from repro.inverse.relax import HARD_TEMP, Lowered

# Adam moments; lr comes from the problem.
_B1, _B2, _EPS = 0.9, 0.999, 1e-8
# Starts evaluated per vmapped solve call (mirrors the sharded sweep's
# chunking: wide start grids stream through fixed-size batches).
START_CHUNK = 16


def _temps(problem: InverseProblem) -> np.ndarray:
    """Geometric annealing schedule temp_hi -> temp_lo over the iters."""
    return np.geomspace(problem.temp_hi, problem.temp_lo, problem.iters)


def _theta_starts(lowered: Lowered) -> np.ndarray:
    """[S, T] start batch: centers first, then uniform in the box."""
    problem = lowered.problem
    rng = np.random.default_rng(problem.seed)
    rows = [lowered.theta0]
    for _ in range(problem.starts - 1):
        rows.append(rng.uniform(lowered.theta_lo, lowered.theta_hi))
    return np.stack(rows)


def _solve_starts(lowered: Lowered, theta0s: np.ndarray,
                  ) -> tuple[np.ndarray, np.ndarray]:
    """Projected Adam on every start: ([S, T] thetas, [S, iters] losses)."""
    problem = lowered.problem
    temps = jnp.asarray(_temps(problem))
    lo_b = jnp.asarray(lowered.theta_lo)
    hi_b = jnp.asarray(lowered.theta_hi)
    lr = problem.lr
    value_and_grad = jax.value_and_grad(lowered.loss)

    def step(carry, temp):
        theta, m, v, t = carry
        loss, g = value_and_grad(theta, temp)
        t = t + 1.0
        m = _B1 * m + (1.0 - _B1) * g
        v = _B2 * v + (1.0 - _B2) * g * g
        m_hat = m / (1.0 - _B1 ** t)
        v_hat = v / (1.0 - _B2 ** t)
        theta = theta - lr * m_hat / (jnp.sqrt(v_hat) + _EPS)
        theta = jnp.clip(theta, lo_b, hi_b)
        return (theta, m, v, t), loss

    def solve_one(theta0):
        zeros = jnp.zeros_like(theta0)
        (theta, _, _, _), losses = jax.lax.scan(
            step, (theta0, zeros, zeros, 0.0), temps)
        return theta, losses

    solve_batch = jax.jit(jax.vmap(solve_one))
    thetas, losses = [], []
    for i in range(0, len(theta0s), START_CHUNK):
        th, ls = solve_batch(jnp.asarray(theta0s[i:i + START_CHUNK]))
        thetas.append(np.asarray(th))
        losses.append(np.asarray(ls))
    return np.concatenate(thetas), np.concatenate(losses)


def grid_argmin(problem: InverseProblem, lowered: Lowered | None = None,
                ) -> dict:
    """The Algorithm-1-style reference: argmin of the problem objective
    over the grid corners x orgs through the standard memoized engine
    path, restricted to the area budget."""
    with enable_x64():
        lowered = lowered if lowered is not None else relax.lower(problem)
        obj, area = lowered.grid_objective()
        ki, oi = lowered.masked_argmin(obj, area)
        return {"point": ki, "org": oi, "value": float(obj[ki, oi]),
                "area_mm2": float(area[ki]),
                "corner": lowered.corner_info(ki, oi),
                "objective_matrix": obj, "areas_mm2": area}


def recover_corner(problem: InverseProblem, lowered: Lowered | None = None,
                   ) -> dict:
    """The relaxed pipeline hardened at the anchor centers: with leaves
    pinned and the softmins at :data:`HARD_TEMP`, the selected (corner,
    org) must recover :func:`grid_argmin`'s winner — the softmin ->
    argmin consistency check."""
    with enable_x64():
        lowered = lowered if lowered is not None else relax.lower(problem)
        obj, area, _ = lowered.objective_matrix(lowered.theta0, HARD_TEMP)
        obj, area = np.asarray(obj), np.asarray(area)
        ki, oi = lowered.masked_argmin(obj, area)
        return {"point": ki, "org": oi, "value": float(obj[ki, oi]),
                "area_mm2": float(area[ki]),
                "corner": lowered.corner_info(ki, oi),
                "objective_matrix": obj}


def _standard_cell(lowered: Lowered, theta: np.ndarray, ki: int):
    """The winning point's bitcell through the standard path: a custom
    device with the converged leaves, assembled over the fin grid with
    ``characterize``'s own min-EDAP rule."""
    p = lowered.points[ki]
    if p.mem == "sram":
        return bitcell_mod.characterize("sram", p.node)
    gi = lowered.relaxed[(int(lowered.nk[ki]), int(lowered.mk[ki]))]
    group = lowered.groups[gi]
    leaves = group.leaves(theta)
    dev = mtj.custom_device(p.mem, p.node, **group.device_overrides(theta))
    cells = [c for fr, fw, shared in bitcell_mod.fin_assignments(p.mem)
             if (c := bitcell_mod.assemble(
                 p.mem, p.node, fr, fw, shared, device=dev,
                 area_base_norm=leaves["area_base_norm"])) is not None]
    if not cells:
        raise ValueError(f"converged {p.mem} leaves are write-infeasible "
                         f"at {p.node.name} (the scaling-wall penalty "
                         "should have prevented this)")
    return min(cells, key=bitcell_mod._edap)


def verify(lowered: Lowered, theta: np.ndarray, ki: int, oi: int) -> dict:
    """Re-evaluate one converged (theta, corner, org) point through the
    standard (non-relaxed) pipeline and report the objective value, the
    materialized :class:`CacheDesign`, and the per-field PPA tensors."""
    with enable_x64():
        p = lowered.points[ki]
        cell = _standard_cell(lowered, theta, ki)
        cal = calibration.get(p.mem, p.node)
        out = engine.evaluate(
            (p.capacity_bytes,), (engine.ORGS[oi],), mems=(p.mem,),
            cells=((cell,),), cals=((cal,),), nodes=p.node)
        ppa = {k: float(np.asarray(v).reshape(-1)[0])
               for k, v in out.items()}
        design = CacheDesign(
            mem=p.mem, capacity_bytes=p.capacity_bytes,
            org=engine.ORGS[oi],
            read_latency_s=ppa["read_latency_s"],
            write_latency_s=ppa["write_latency_s"],
            read_energy_j=ppa["read_energy_j"],
            write_energy_j=ppa["write_energy_j"],
            leakage_w=ppa["leakage_w"],
            area_mm2=ppa["area_mm2"])
        if lowered.problem.objective == "edap":
            value = float(design.edap)
        else:
            spec = lowered.problem.sweep.resolve()
            tables = workload_engine.evaluate_platforms(
                spec.scenarios, (design,), spec.platforms)
            edp = np.stack([t.edp(lowered.problem.include_dram)
                            for t in tables])
            value = float(edp.mean())
        return {"value": value, "design": design, "ppa": ppa, "cell": cell}


def solve(problem: InverseProblem) -> InverseResult:
    """Full inverse solve: lower, multi-start descent, harden, pick the
    best area-feasible start, verify through the standard path."""
    with enable_x64():
        lowered = relax.lower(problem)
        theta0s = _theta_starts(lowered)
        thetas, losses = _solve_starts(lowered, theta0s)

        harden = jax.jit(
            lambda th: lowered.objective_matrix(th, HARD_TEMP)[:2])
        best = None
        for si in range(len(thetas)):
            obj, area = (np.asarray(a) for a in harden(thetas[si]))
            try:
                ki, oi = lowered.masked_argmin(obj, area)
            except ValueError:
                continue
            value = float(obj[ki, oi])
            if best is None or value < best[0]:
                best = (value, si, ki, oi)
        if best is None:
            raise ValueError(f"{problem.name}: no start produced an "
                             "area-feasible design")
        value, si, ki, oi = best
        theta = thetas[si]

        checked = verify(lowered, theta, ki, oi)
        parity = abs(value - checked["value"]) / abs(checked["value"])
        grid = grid_argmin(problem, lowered)

        active: dict[str, object] = {}
        for g in lowered.groups:
            for leaf, side in g.at_bound(theta).items():
                active[f"{g.flavor}/{g.node.name}.{leaf}"] = side
        budget = lowered.area_budget_mm2
        area_mm2 = checked["design"].area_mm2
        if budget is not None and area_mm2 >= 0.99 * budget:
            active["area_budget_mm2"] = True

        return InverseResult(
            problem=problem,
            leaves={g.key: g.leaves(theta) for g in lowered.groups},
            objective=problem.objective,
            best_value=value,
            standard_value=checked["value"],
            parity_rel_err=float(parity),
            grid_best_value=grid["value"],
            corner=lowered.corner_info(ki, oi),
            design=checked["design"],
            area_mm2=area_mm2,
            area_budget_mm2=budget,
            trajectory=tuple(float(x) for x in losses[si]),
            start_losses=tuple(float(x) for x in losses[:, -1]),
            converged_start=si,
            iterations=problem.iters,
            n_starts=problem.starts,
            active_constraints=active)

"""Jitted public wrappers: backend dispatch + padding + GQA expansion.

`attention(...)` is what the model layer calls: Pallas kernel on TPU,
custom-VJP chunked reference elsewhere (this CPU container), naive SDPA
for short sequences where the quadratic logits are cheap.
"""

from __future__ import annotations

import jax

from repro.kernels import flash_attention as fa
from repro.kernels import ref
from repro.kernels import rwkv6 as wkv

# below this q-length, naive SDPA is used (cheapest at small S)
FLASH_THRESHOLD = 2048


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              q_offset: int = 0, scale: float | None = None,
              force: str | None = None) -> jax.Array:
    """Dispatching attention.  q: (B,Sq,H,hd); k,v: (B,Skv,H,hd), H equal
    (expand GQA upstream)."""
    sq, skv = q.shape[1], k.shape[1]
    impl = force or ("naive" if sq < FLASH_THRESHOLD
                     else ("pallas" if _on_tpu() else "ref"))
    if impl == "naive":
        return ref.naive_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, scale=scale)
    if impl == "pallas":
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)
    block_k = min(512, skv)
    return ref.flash_attention_ref(q, k, v, block_k, causal, window,
                                   q_offset, scale)


def rwkv_mix(r, k, v, w, u, *, force: str | None = None):
    """WKV6: Pallas chunked kernel on TPU, sequential-scan ref elsewhere.
    Returns (y, s_final); the Pallas path recomputes s_final cheaply from
    the ref tail when a carry is needed (training uses y only)."""
    impl = force or ("pallas" if _on_tpu() else "ref")
    if impl == "pallas":
        y = wkv.wkv6(r, k, v, w, u)
        return y, None
    return ref.wkv6_ref(r, k, v, w, u)

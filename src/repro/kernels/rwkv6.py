"""Pallas TPU kernel for the RWKV6 (WKV) recurrence — chunked form.

The WKV recurrence S_t = diag(w_t) S_{t-1} + k_t^T v_t is sequential, but
within a chunk of C tokens the contribution of the chunk-initial state and
the intra-chunk pairs can be computed with dense matmuls (MXU-friendly):

    y_t = r_t (prod_{j<=t} w_j) S_0 + sum_{i<t} r_t (prod_{i<j<=t} w_j)
          k_i^T v_i + r_t (u * k_t^T v_t)

Grid: (batch*heads,); the kernel walks chunks with fori_loop, carrying the
(hd, hd) state in VMEM scratch.  Tiles sized (C=128, hd<=128) align with
the MXU.  Validated in interpret mode against kernels/ref.wkv6_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scratch,
                *, chunk: int, seq: int):
    hd = r_ref.shape[-1]
    s_scratch[...] = jnp.zeros((hd, hd), jnp.float32)
    u = u_ref[...].astype(jnp.float32)                     # (hd,)
    n_chunks = seq // chunk

    def body(ci, _):
        sl = (pl.dslice(ci * chunk, chunk), slice(None))
        r = pl.load(r_ref, sl).astype(jnp.float32)         # (C,hd)
        k = pl.load(k_ref, sl).astype(jnp.float32)
        v = pl.load(v_ref, sl).astype(jnp.float32)
        w = pl.load(w_ref, sl).astype(jnp.float32)
        logw = jnp.log(jnp.maximum(w, 1e-30))
        cum = jnp.cumsum(logw, axis=0)                     # (C,hd) inclusive
        cum_ex = cum - logw                                # exclusive: j < t
        # state contribution: r_t * prod_{j<t} w_j applied to S_0
        r_dec = r * jnp.exp(cum_ex)
        s0 = s_scratch[...]
        y_state = jax.lax.dot_general(r_dec, s0, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        # intra-chunk: A[t,i] = sum_d r[t,d] k[i,d] exp(cum_ex[t,d]-cum[i,d])
        # factorized as a masked matmul; normalize by the mid-chunk decay so
        # neither factor over/underflows (valid while the per-chunk decay
        # range stays within fp32 exponent headroom — chunk=128 with
        # realistic RWKV decays; see module docstring)
        c_mid = cum[chunk // 2, :][None, :]
        r_sc = r * jnp.exp(cum_ex - c_mid)                 # (C,hd)
        k_sc = k * jnp.exp(c_mid - cum)                    # (C,hd)
        att = jax.lax.dot_general(r_sc, k_sc, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
        att = jnp.where(t_idx > i_idx, att, 0.0)           # strict past
        y_intra = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
        # current-token bonus: r_t (u * k_t) v_t
        bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
        y = y_state + y_intra + bonus
        pl.store(y_ref, sl, y.astype(y_ref.dtype))
        # carry state: S <- diag(prod w) S_0 + sum_i (prod_{j>i} w) k_i v_i
        decay_all = jnp.exp(cum[-1, :])                    # (hd,)
        k_tail = k * jnp.exp(cum[-1:, :] - cum)            # (C,hd)
        s_new = decay_all[:, None] * s0 + jax.lax.dot_general(
            k_tail, v, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        s_scratch[...] = s_new
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = 128, interpret: bool = False):
    """Chunk-parallel WKV6.  r,k,v,w: (B,S,H,hd); u: (H,hd).
    S % chunk == 0.  Returns y: (B,S,H,hd)."""
    b, s, h, hd = r.shape
    fold = lambda t: jnp.moveaxis(t, 2, 1).reshape(b * h, s, hd)  # noqa: E731
    rr, kk, vv, ww = fold(r), fold(k), fold(v), fold(w)
    uu = u.reshape(h, hd)
    uu = jnp.broadcast_to(uu[None], (b, h, hd)).reshape(b * h, hd)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, seq=s)
    y = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((None, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, s, hd), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, hd), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((None, s, hd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu)
    return jnp.moveaxis(y.reshape(b, h, s, hd), 1, 2)

"""Pallas TPU flash-attention kernel (forward).

TPU-native blocking: q tiles of (BLOCK_Q, head_dim) live in VMEM and loop
over kv tiles of (BLOCK_K, head_dim) on the MXU, maintaining the online
softmax (m, l, acc) in VREGs/VMEM — the FlashAttention algorithm re-tiled
for the HBM->VMEM->MXU hierarchy rather than CUDA shared memory (DESIGN.md
"hardware adaptation").  Tiles are multiples of 128 to match MXU/VPU lane
dims.  Grid: (batch*heads, Sq/BLOCK_Q); the kv loop is a fori_loop inside
the kernel so kv tiles stream through VMEM.

Validated in interpret mode against kernels/ref.py on CPU (tests/
test_kernels.py); the backward pass reuses the custom-VJP recompute of
flash_attention_ref (fwd-kernel + recompute-bwd is the standard serving
configuration; a Pallas bwd kernel is a further optimization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                  window: int | None, q_offset: int, scale: float,
                  seq_kv: int):
    """One (bh, q_block) grid cell.  Refs: q (BQ,hd); k/v (Skv,hd)."""
    block_q, hd = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale
    q_base = pl.program_id(1) * block_q + q_offset
    q_pos = q_base + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(i, carry):
        m, l, acc = carry
        ks = pl.load(k_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        vs = pl.load(v_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        s = jax.lax.dot_general(q, ks.astype(jnp.float32),
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, vs.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    n_kv = seq_kv // block_k
    if causal and window is None:
        # skip fully-masked kv tiles: only blocks with k_base <= q_max
        q_max = q_base + block_q - 1
        n_eff = jnp.minimum(n_kv, (q_max // block_k) + 1)
    else:
        n_eff = n_kv
    m, l, acc = jax.lax.fori_loop(0, n_eff, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "window", "q_offset",
                                             "interpret"))
def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    causal: bool = True, window: int | None = None,
                    q_offset: int = 0, interpret: bool = False):
    """q: (B,Sq,H,hd); k,v: (B,Skv,H,hd) with H already GQA-expanded.
    Sq % block_q == 0 and Skv % block_k == 0 (pad upstream)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    scale = hd ** -0.5
    # fold batch and heads into the grid's leading dim
    qr = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(b * h, skv, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(b * h, skv, hd)

    kernel = functools.partial(_flash_kernel, block_k=block_k, causal=causal,
                               window=window, q_offset=q_offset, scale=scale,
                               seq_kv=skv)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((None, skv, hd), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((None, skv, hd), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return jnp.moveaxis(out.reshape(b, h, sq, hd), 1, 2)

"""Pure-jnp oracles for every Pallas kernel + the memory-efficient
reference implementations the model uses on non-TPU backends.

`flash_attention_ref` is both: a chunked online-softmax attention with a
custom VJP (recompute in backward — activation memory O(S * chunk) instead
of O(S^2)), numerically equivalent to naive SDPA.  `naive_attention` is the
plain quadratic oracle the tests compare everything against.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def naive_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, q_offset: int = 0,
                    scale: float | None = None) -> jax.Array:
    """Quadratic SDPA oracle.  q: (B,Sq,H,hd); k,v: (B,Skv,H,hd)."""
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else scale
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    sq, skv = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _flash_fwd(q, k, v, *, block_k: int, causal: bool, window,
               q_offset: int, scale: float):
    """One pass of online-softmax over kv blocks.  Shapes as naive, plus:
    k/v may have a single shared head (MLA latent attention) and v may have
    a different feature dim than q/k."""
    b, sq, h, hd = q.shape
    hd_v = v.shape[-1]
    shared_kv = k.shape[2] == 1 and h > 1
    skv = k.shape[1]
    nkv = skv // block_k
    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(sq) + q_offset
    kv_eq = "bqhd,bkd->bhqk" if shared_kv else "bqhd,bkhd->bhqk"
    pv_eq = "bhqk,bkd->bhqd" if shared_kv else "bhqk,bkhd->bhqd"

    def body(carry, i):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, i * block_k, block_k, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * block_k, block_k, 1)
        if shared_kv:
            ks, vs = ks[:, :, 0, :], vs[:, :, 0, :]
        s = jnp.einsum(kv_eq, qf, ks.astype(jnp.float32))
        k_pos = i * block_k + jnp.arange(block_k)
        mask = jnp.ones((sq, block_k), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            pv_eq, p, vs.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, hd_v), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(nkv))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype), (m, l)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_ref(q, k, v, block_k: int = 512, causal: bool = True,
                        window: int | None = None, q_offset: int = 0,
                        scale: float | None = None):
    """Memory-efficient attention: O(Sq*block_k) live logits; exact."""
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else scale
    out, _ = _flash_fwd(q, k, v, block_k=block_k, causal=causal,
                        window=window, q_offset=q_offset, scale=scale)
    return out


def _flash_vjp_fwd(q, k, v, block_k, causal, window, q_offset, scale):
    hd = q.shape[-1]
    scale_v = hd ** -0.5 if scale is None else scale
    out, (m, l) = _flash_fwd(q, k, v, block_k=block_k, causal=causal,
                             window=window, q_offset=q_offset, scale=scale_v)
    return out, (q, k, v, out, m, l)


def _flash_vjp_bwd(block_k, causal, window, q_offset, scale, res, dout):
    q, k, v, out, m, l = res
    hd = q.shape[-1]
    scale_v = hd ** -0.5 if scale is None else scale
    b, sq, h, _ = q.shape
    skv = k.shape[1]
    shared_kv = k.shape[2] == 1 and h > 1
    nkv = skv // block_k
    qf = q.astype(jnp.float32)
    do = dout.astype(jnp.float32)
    # delta = rowsum(dO * O)
    delta = jnp.einsum("bqhd,bqhd->bhq", do, out.astype(jnp.float32))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    q_pos = jnp.arange(sq) + q_offset
    kv_eq = "bqhd,bkd->bhqk" if shared_kv else "bqhd,bkhd->bhqk"
    sk_eq = "bhqk,bkd->bqhd" if shared_kv else "bhqk,bkhd->bqhd"
    dk_eq = "bhqk,bqhd->bkd" if shared_kv else "bhqk,bqhd->bkhd"

    def body(dq_acc, i):
        ks = jax.lax.dynamic_slice_in_dim(k, i * block_k, block_k, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * block_k, block_k, 1)
        if shared_kv:
            ks, vs = ks[:, :, 0, :], vs[:, :, 0, :]
        s = jnp.einsum(kv_eq, qf * scale_v, ks.astype(jnp.float32))
        k_pos = i * block_k + jnp.arange(block_k)
        mask = jnp.ones((sq, block_k), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                      # (B,H,Sq,bk)
        dp = jnp.einsum("bqhe,bke->bhqk" if shared_kv else "bqhe,bkhe->bhqk",
                        do, vs.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale_v
        dq_acc = dq_acc + jnp.einsum(sk_eq, ds, ks.astype(jnp.float32))
        dk_i = jnp.einsum(dk_eq, ds, qf)
        dv_i = jnp.einsum("bhqk,bqhe->bke" if shared_kv else
                          "bhqk,bqhe->bkhe", p, do)
        return dq_acc, (dk_i, dv_i)

    dq0 = jnp.zeros((b, sq, h, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, jnp.arange(nkv))
    dk = jnp.moveaxis(dks, 0, 1).reshape(*k.shape)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(*v.shape)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention_ref.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# RWKV6 WKV oracle (sequential recurrence, matches kernels/rwkv6.py)
# ---------------------------------------------------------------------------


def wkv6_ref(r, k, v, w, u, s0=None):
    """Sequential WKV6.  r,k,v,w: (B,S,H,hd); u: (H,hd); s0: (B,H,hd,hd).
    Returns (y: (B,S,H,hd), s_final)."""
    b, s, h, hd = r.shape
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    s_init = (jnp.zeros((b, h, hd, hd), jnp.float32) if s0 is None
              else s0.astype(jnp.float32))

    def step(carry, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", rt, carry + u[None, :, :, None] * kv)
        carry = wt[..., :, None] * carry + kv
        return carry, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    s_fin, ys = jax.lax.scan(step, s_init, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin

"""Fault tolerance & straggler mitigation for the training driver.

  * `StragglerDetector` — per-step wall-time EWMA with robust z-score; a
    host whose step times exceed `threshold` sigma flags itself (on real
    multi-host deployments this feeds the coordinator's restart/evict
    decision; single-process here, the mechanism is identical).
  * `RestartPolicy` — crash-loop accounting: bounded restarts within a
    window, exponential backoff.
  * `run_resilient` — wraps a step function with checkpoint/restore so a
    raised fault (or injected test fault) resumes from the last checkpoint
    — the integration tests kill the loop mid-run and assert bitwise
    recovery of progress.
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1          # EWMA factor
    threshold: float = 3.0      # sigma
    warmup: int = 10
    mean: float = 0.0
    var: float = 0.0
    n: int = 0

    def observe(self, step_time_s: float) -> bool:
        """Record one step; True if this step looks like a straggler.

        The z-score is computed against the *pre-update* statistics so an
        outlier cannot mask itself by inflating the EWMA it is judged by.
        """
        self.n += 1
        if self.n == 1:
            self.mean = step_time_s
            return False
        sigma = max(self.var ** 0.5, 1e-9)
        is_straggler = (self.n >= self.warmup
                        and (step_time_s - self.mean) / sigma > self.threshold)
        delta = step_time_s - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        return is_straggler


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    window_s: float = 3600.0
    backoff_s: float = 1.0
    history: list = dataclasses.field(default_factory=list)

    def should_restart(self, now: float | None = None) -> bool:
        now = time.time() if now is None else now
        self.history = [t for t in self.history if now - t < self.window_s]
        return len(self.history) < self.max_restarts

    def record(self, now: float | None = None) -> float:
        """Record a restart; returns the backoff delay to apply."""
        now = time.time() if now is None else now
        self.history.append(now)
        return self.backoff_s * (2 ** (len(self.history) - 1))


def run_resilient(state, data, step_fn, manager, *, n_steps: int,
                  checkpoint_every: int = 10,
                  fault_at: int | None = None, _policy=None):
    """Checkpoint/restart training loop.

    `fault_at`: injects a crash at that step (tests).  On any exception the
    loop restores the latest checkpoint and continues; data batches are
    addressed by step so no data is replayed or skipped.
    """
    policy = _policy or RestartPolicy()
    detector = StragglerDetector()
    faults_remaining = 1 if fault_at is not None else 0
    metrics_log = []
    step = int(state.step)
    while step < n_steps:
        try:
            t0 = time.time()
            if faults_remaining and step == fault_at:
                faults_remaining -= 1
                raise RuntimeError(f"injected fault at step {step}")
            batch = data.batch(step)
            state, metrics = step_fn(state, batch)
            straggler = detector.observe(time.time() - t0)
            metrics["straggler"] = straggler
            metrics_log.append({k: float(v) if hasattr(v, "item") or
                                isinstance(v, (int, float)) else v
                                for k, v in metrics.items()})
            step = int(state.step)
            if step % checkpoint_every == 0:
                manager.save(step, state)
        except Exception as e:  # noqa: BLE001 — resilience boundary
            if not policy.should_restart():
                raise
            delay = policy.record()
            print(f"fault: {e}; restarting (backoff {delay:.1f}s)")
            restored_step, restored = manager.restore_latest(state)
            if restored is not None:
                state = restored
                step = restored_step
            else:
                step = 0
    manager.wait()
    return state, metrics_log

"""Sharding rules: map every param/cache/batch leaf to a PartitionSpec.

Scheme (Megatron-style TP on `model`, DP/FSDP on `data` (+`pod`)):

  * embeddings       vocab on `model` (fallback: d_model)
  * attention q/o    heads on `model`; k/v heads on `model` when divisible
  * MLP              d_ff on `model`
  * MoE experts      expert dim on `model` (expert parallelism)
  * FSDP (optional)  largest remaining dim over `data` (+`pod`)
  * batch/caches     batch on (`pod`,`data`); seq on `model` for batch-1
                     long-context caches; replicate what does not divide

Divisibility is never assumed: each rule emits an ordered list of
candidate (dim -> axis) assignments and `best_fit` keeps the first ones
that divide — e.g. Qwen3's 40 heads don't split over model=16, so TP falls
back to sharding d_model; MiniCPM's 122753-token vocab falls back the same
way.  This is what makes one rule set serve all 10 architectures.
"""

from __future__ import annotations

from collections.abc import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"

# ---------------------------------------------------------------------------
# Sweep-engine mesh (core/sweep.py ShardPlan): a 1-D data-parallel mesh the
# chunked mega-sweep lowering shard_maps the workload fold over.  Chunks are
# independent [scenario, design] blocks, so the only axis is the chunk axis.
# ---------------------------------------------------------------------------

SWEEP_AXIS = "sweep"


def sweep_mesh(devices: int | None = None) -> Mesh:
    """A 1-D mesh of the first ``devices`` local devices (default: all) on
    the ``sweep`` axis.  On CPU, ``XLA_FLAGS=--xla_force_host_platform_
    device_count=N`` provides N host devices to shard over."""
    devs = jax.devices()
    n = len(devs) if devices is None else int(devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"requested {n} devices; "
                         f"{len(devs)} available ({devs[0].platform})")
    return Mesh(np.array(devs[:n]), (SWEEP_AXIS,))


def sweep_chunk_spec() -> P:
    """PartitionSpec of a stacked chunk tensor: the leading chunk axis is
    split over the sweep mesh, everything else stays local."""
    return P(SWEEP_AXIS)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Data-parallel axes: `data`, plus `pod` folded in when present."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if isinstance(axis, tuple):
        sz = 1
        for a in axis:
            sz *= mesh.shape[a]
        return sz
    return mesh.shape[axis]


def best_fit(shape: Sequence[int], mesh: Mesh,
             preferences: Sequence[tuple[int, object]]) -> P:
    """Greedy first-fit: keep each (dim, axis) whose size divides the dim
    and whose axis is still unused; replicate everything else."""
    assignment: dict[int, object] = {}
    used: set[str] = set()
    for dim, axis in preferences:
        if dim >= len(shape) or dim in assignment:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        if any(a not in mesh.axis_names or a in used for a in axes):
            continue
        if shape[dim] % _axis_size(mesh, axis) != 0 or shape[dim] == 0:
            continue
        assignment[dim] = axis
        used.update(axes)
    return P(*[assignment.get(i) for i in range(len(shape))])


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def param_spec(path, leaf, mesh: Mesh, *, fsdp: bool = False,
               stacked: bool = True) -> P:
    """PartitionSpec for one model/optimizer parameter leaf.

    `stacked`: leaves inside scan segments have a leading layer dim that is
    never sharded; rules index dims relative to the per-layer shape.
    """
    name = _path_str(path)
    shape = leaf.shape
    off = 1 if (stacked and ("seg" in name or "encoder" in name)
                and leaf.ndim >= 2) else 0
    dp = dp_axes(mesh)
    prefs: list[tuple[int, object]] = []

    def pref(dim_rel: int, axis):
        prefs.append((dim_rel + off, axis))

    nd = leaf.ndim - off
    if "embed" in name:                       # (vocab, d) / (d, vocab)
        big = 0 if shape[0] >= (shape[1] if leaf.ndim > 1 else 0) else 1
        prefs.append((big, MODEL_AXIS))
        prefs.append((1 - big, dp if fsdp else MODEL_AXIS))
    elif any(k in name for k in ("wi_gate", "wi_up", "wo", "wk", "wv", "wq",
                                 "wr", "wg", "router", "in_proj", "out_proj",
                                 "x_proj", "dt_proj", "w_lora", "proj",
                                 "shared", "cmix", "wq_a", "wq_b", "wkv_a",
                                 "wk_b", "wv_b")):
        if nd == 3 and ("ffn/wi" in name or "ffn/wo" in name):
            # MoE experts (E, d, f): expert parallelism
            pref(0, MODEL_AXIS)
            if fsdp:
                pref(2, dp)
                pref(1, dp)
        elif nd == 3:                          # (d, H, hd) attention
            pref(1, MODEL_AXIS)               # heads on model
            pref(0, MODEL_AXIS)               # fallback: d_model
            if fsdp:
                pref(0, dp)
        elif nd == 2:
            # 2-D matrices: shard the bigger dim on model, other on data
            big = 0 if shape[off] >= shape[off + (1 if nd > 1 else 0)] else 1
            pref(big, MODEL_AXIS)
            pref(1 - big, MODEL_AXIS)
            if fsdp:
                pref(1 - big, dp)
                pref(big, dp)
        elif nd == 1 and fsdp:
            pref(0, dp)
    elif nd >= 2 and fsdp:
        pref(0, dp)
    return best_fit(shape, mesh, prefs)


def cache_spec(path, leaf, mesh: Mesh) -> P:
    """KV/state caches: batch on dp; heads/latent on model; batch-1 long
    caches shard the sequence dim on model instead."""
    name = _path_str(path)
    shape = leaf.shape
    dp = dp_axes(mesh)
    # stacked layer dim leads: (L, B, ...)
    off = 1 if "seg" in name else 0
    prefs: list[tuple[int, object]] = [(off, dp)]
    if "pos" in name:
        return P(*([None] * leaf.ndim))
    if "ckv" in name or "krope" in name:      # MLA latent (L,B,S,r)
        prefs.append((off + 1, MODEL_AXIS))   # seq on model
    elif leaf.ndim - off == 4 and ("k" in name or "v" in name):
        prefs.append((off + 2, MODEL_AXIS))   # kv heads
        prefs.append((off + 1, MODEL_AXIS))   # fallback: seq
    elif "s" in name and leaf.ndim - off == 4:   # rwkv state (B,H,hd,hd)
        prefs.append((off + 1, MODEL_AXIS))
        prefs.append((off + 2, MODEL_AXIS))
    elif leaf.ndim - off >= 2:
        prefs.append((off + 1, MODEL_AXIS))
    return best_fit(shape, mesh, prefs)


def batch_spec(leaf, mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    if leaf.ndim == 0:
        return P()
    prefs = [(0, dp), (0, "data")]
    if leaf.ndim >= 2:
        prefs.append((1, MODEL_AXIS))  # batch-1 long context: shard seq
    return best_fit(leaf.shape, mesh, prefs)


def tree_specs(tree, mesh: Mesh, kind: str, **kw):
    """Map a pytree of (abstract) arrays to PartitionSpecs."""
    fn = {"param": param_spec, "cache": cache_spec}[kind]
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fn(p, x, mesh, **kw), tree)


def tree_shardings(tree, mesh: Mesh, kind: str, **kw):
    specs = tree_specs(tree, mesh, kind, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(batch, mesh: Mesh):
    return jax.tree.map(lambda x: NamedSharding(mesh, batch_spec(x, mesh)),
                        batch)


# ---------------------------------------------------------------------------
# Activation sharding constraints (SSPerf lever): explicit Megatron-style
# annotations at block boundaries so GSPMD never falls back to involuntary
# full rematerialization (replicate-then-reshard all-gathers of whole
# activations, the dominant collective cost in the baseline dry-runs).
# ---------------------------------------------------------------------------

_ACT = {"mesh": None, "seq_parallel": False}


def enable_activation_sharding(mesh: Mesh | None,
                               seq_parallel: bool = False) -> None:
    """None disables.  seq_parallel shards the residual stream's sequence
    dim over `model` (norms/elementwise run sequence-parallel; GSPMD turns
    the block-boundary all-reduces into reduce-scatter + all-gather)."""
    _ACT["mesh"] = mesh
    _ACT["seq_parallel"] = seq_parallel


def constrain(x, kind: str):
    """Annotate activation `x`.  kinds:
    residual (B,S,d) | heads (B,S,H,hd) | hidden (B,S,f) | logits (B,S,V)
    """
    mesh = _ACT["mesh"]
    if mesh is None:
        return x
    dp = dp_axes(mesh)
    seq = MODEL_AXIS if _ACT["seq_parallel"] else None
    if kind == "residual":
        prefs = [(0, dp)] + ([(1, MODEL_AXIS)] if seq else [])
    elif kind == "heads":
        prefs = [(0, dp), (2, MODEL_AXIS)]
    elif kind in ("hidden", "logits"):
        prefs = [(0, dp), (x.ndim - 1, MODEL_AXIS)]
    else:
        raise ValueError(kind)
    spec = best_fit(x.shape, mesh, prefs)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

"""Distribution: sharding rules, compression, fault tolerance, elasticity."""

"""Error-feedback gradient compression (cross-pod traffic reduction).

Two compressors, both with error feedback (the residual of each step is
added back before the next compression, preserving convergence):

  * int8 quantization — 4x traffic vs f32, dense.
  * top-k sparsification — keep the k largest-magnitude entries per leaf.

`EFCompressor.transform` plugs into optim.make_train_step(grad_transform=)
to compress the gradient pytree before the (implicit, GSPMD-inserted)
cross-replica reduction; on a manual shard_map DP path the quantized
representation is what crosses the pod links.  State (error buffers) lives
alongside the optimizer state and checkpoints with it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jax.Array, frac: float) -> jax.Array:
    """Zero all but the top-|frac| fraction of entries (per leaf)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


@dataclasses.dataclass
class EFCompressor:
    """Error-feedback wrapper around one of the compressors."""

    kind: str = "int8"       # "int8" | "topk" | "none"
    topk_frac: float = 0.05

    def init(self, params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def __call__(self, grads, error):
        """Returns (compressed_grads, new_error)."""
        if self.kind == "none":
            return grads, error

        def one(g, e):
            g = g.astype(jnp.float32) + e
            if self.kind == "int8":
                q, s = quantize_int8(g)
                out = dequantize_int8(q, s)
            else:
                out = topk_sparsify(g, self.topk_frac)
            return out, g - out

        pairs = jax.tree.map(one, grads, error)
        comp = jax.tree.map(lambda pe: pe[0], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree.map(lambda pe: pe[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        return comp, err

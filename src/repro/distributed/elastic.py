"""Elastic scaling: re-shard a training state onto a different mesh.

When the device pool changes (node failure shrinks a pod, or capacity
returns), the checkpointed state must be re-laid-out for the new mesh.
Because sharding rules (distributed/sharding.py) are *functions of the
mesh*, elasticity is: load (host) state -> compute specs for the new mesh
-> device_put each leaf with its new NamedSharding.  Batches keep their
step addressing (data/pipeline.py), so training resumes exactly where it
left off with a different data-parallel width — only throughput changes.
"""

from __future__ import annotations

import jax

from repro.distributed import sharding


def reshard_state(state, new_mesh, *, fsdp: bool = False):
    """Place every leaf of `state` onto `new_mesh` under the rule set."""
    shardings = sharding.tree_shardings(state, new_mesh, "param", fsdp=fsdp)
    return jax.tree.map(
        lambda leaf, sh: jax.device_put(leaf, sh), state, shardings)


def rebalance_batch(global_batch: int, new_mesh) -> int:
    """Per-host batch after an elastic resize (global batch preserved when
    divisible; otherwise the largest divisible batch <= requested)."""
    dp = 1
    for a in sharding.dp_axes(new_mesh):
        dp *= new_mesh.shape[a]
    return (global_batch // dp) * dp

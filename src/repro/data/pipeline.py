"""Deterministic sharded data pipeline.

Offline container => synthetic corpus, but with the properties a real
pipeline needs at 1000-node scale:

  * **Deterministic addressing**: batch `i` is a pure function of
    (seed, step, host) — any host can reproduce any batch, so restarts and
    elastic re-sharding never replay or skip data.
  * **Host sharding**: each host materializes only its slice of the global
    batch (`host_slice`), matching the (`pod`,`data`) mesh axes.
  * **Prefetch**: a depth-2 background iterator overlaps host data
    generation with device compute.
  * Markov-chain token stream (not uniform noise) so the LM loss actually
    decreases in the examples — useful for the end-to-end train driver.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0
    branching: int = 32   # Markov out-degree: lower => easier to model


class SyntheticTokens:
    """Deterministic Markov token stream, shardable by host."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sparse deterministic transition table: vocab x branching
        self.table = rng.integers(0, cfg.vocab,
                                  size=(cfg.vocab, cfg.branching),
                                  dtype=np.int32)

    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.n_hosts

    def batch(self, step: int) -> dict:
        """The host's shard of global batch `step` (pure function)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_index, 0xD5EE))
        b = self.host_batch
        toks = np.empty((b, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
        choices = rng.integers(0, cfg.branching, size=(b, cfg.seq_len))
        for t in range(cfg.seq_len):
            toks[:, t + 1] = self.table[toks[:, t], choices[:, t]]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def prefetch(self, start_step: int = 0, depth: int = 2):
        """Background-producing iterator starting at `start_step`."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                q.put((step, self.batch(step)))
                step += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

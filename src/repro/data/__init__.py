from repro.data.pipeline import SyntheticTokens, DataConfig  # noqa: F401

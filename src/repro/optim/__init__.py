from repro.optim.optimizer import (  # noqa: F401
    AdamWConfig, TrainState, adamw_init, adamw_update, global_norm,
    make_train_step,
)
from repro.optim.schedules import cosine, linear_warmup, wsd  # noqa: F401

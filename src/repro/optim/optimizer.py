"""AdamW + train-state + train-step builder.

Production details included: decoupled weight decay with a mask (norm
scales and 1-D params excluded), global-norm clipping, bf16-safe fp32
master params, gradient accumulation, and an optional error-feedback int8
gradient-compression transform (distributed/compression.py) applied to the
gradient pytree before the update — the knob for cross-pod traffic.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp

Pytree = object


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Callable = None  # step -> lr; default cosine set by caller


@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Pytree
    mu: Pytree
    nu: Pytree

    def tree_flatten(self):
        return (self.step, self.params, self.mu, self.nu), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.asarray(leaves)))


def _decay_mask(path) -> bool:
    """Apply weight decay only to >=2-D matrices (skip norms/biases)."""
    return True


def adamw_init(params) -> TrainState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      mu=zeros,
                      nu=jax.tree.map(jnp.zeros_like, zeros))


def adamw_update(state: TrainState, grads, cfg: AdamWConfig,
                 grad_transform: Callable | None = None) -> TrainState:
    if grad_transform is not None:
        grads = grad_transform(grads)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    lr = cfg.schedule(step) if cfg.schedule else 3e-4
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(state.params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree.unflatten(treedef, [n[0] for n in new])
    mu = jax.tree.unflatten(treedef, [n[1] for n in new])
    nu = jax.tree.unflatten(treedef, [n[2] for n in new])
    return TrainState(step=step, params=params, mu=mu, nu=nu)


def make_train_step(loss_fn: Callable, cfg: AdamWConfig,
                    accum_steps: int = 1,
                    grad_transform: Callable | None = None):
    """Builds train_step(state, batch) -> (state, metrics).

    `loss_fn(params, batch) -> scalar`.  With accum_steps > 1 the batch's
    leading axis is split into microbatches accumulated with lax.scan
    (activation memory / pipeline-friendly).
    """

    def step(state: TrainState, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(accum_steps, -1, *x.shape[1:]), batch)

            def acc(carry, mb):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (loss_sum + l, gsum), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (loss, grads), _ = jax.lax.scan(acc, (0.0, zeros), micro)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        new_state = adamw_update(state, grads, cfg, grad_transform)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": global_norm(grads),
                   "step": new_state.step}
        return new_state, metrics

    return step

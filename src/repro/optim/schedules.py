"""LR schedules: cosine, linear, and WSD (warmup-stable-decay, MiniCPM)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int, peak: float):
    return peak * jnp.minimum(1.0, (step + 1) / max(1, warmup))


def cosine(step, *, peak: float, warmup: int, total: int,
           final_frac: float = 0.1):
    warm = linear_warmup(step, warmup, peak)
    t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, peak * cos)


def wsd(step, *, peak: float, warmup: int, total: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM): flat peak LR, sharp exponential-ish
    decay over the last `decay_frac` of training."""
    warm = linear_warmup(step, warmup, peak)
    decay_start = total * (1 - decay_frac)
    t = jnp.clip((step - decay_start) / max(1.0, total - decay_start), 0.0, 1.0)
    stable = peak * jnp.power(final_frac, t)   # exp decay to final_frac*peak
    return jnp.where(step < warmup, warm, stable)

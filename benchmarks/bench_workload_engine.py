"""Workload-engine benchmark: the fig3-10 workload fold, loop vs batched.

Times the architecture-layer pass behind Figs. 3-10 — iso-capacity rows
(Figs. 3/4), the batch sweep (Fig. 5), the DRAM reduction curve (Fig. 6),
iso-area rows (Figs. 7/8), and the capacity scaling sweep (Figs. 9/10) —
two ways:

  loop     the pre-engine implementation: one scalar ``traffic.build`` +
           ``traffic.energy`` / ``dram_tx`` call per (workload, stage,
           memory, capacity), statistics rebuilt per analysis, exactly as
           isocap/isoarea/scaling did before the workload engine;
  batched  the rewired analyses — shared memoized TrafficStats and one
           jitted [scenario] x [design] fold per analysis.

Tuned cache designs (the circuit layer) are prefetched before either
pass, so the comparison isolates the workload fold.  Cross-checks that
the two paths produce the same rows, then writes the timing comparison to
benchmarks/BENCH_workload_engine.json (run from the repo root).
"""

from __future__ import annotations

import json
import os
import statistics
import time

from repro.core import isoarea, isocap, scaling, sweep, traffic, \
    workload_engine
from repro.core.isocap import (CAPACITY_MB, INFER_BATCH, TRAIN_BATCH,
                               IsoCapRow, MEMS)
from repro.core.scaling import CAPACITIES_MB, ScalingRow
from repro.core.workloads import alexnet, paper_workloads

JSON_PATH = "benchmarks/BENCH_workload_engine.json"
REPS = 5
BATCHES = (1, 2, 4, 8, 16, 32, 64)
DRAM_CAPS_MB = (3, 6, 7, 10, 12, 24)
STAGES = ((False, INFER_BATCH), (True, TRAIN_BATCH))


# -- loop (pre-engine) implementations of the five figure passes -----------


def _loop_stage_rows(designs: dict) -> list[IsoCapRow]:
    """isocap/isoarea.analyze as the seed wrote them: fresh statistics and
    one scalar energy fold per (workload, stage, memory)."""
    rows = []
    for w in paper_workloads().values():
        for training, batch in STAGES:
            stats = traffic.build(w, batch, training)
            reports = {m: traffic.energy(stats, d)
                       for m, d in designs.items()}
            rows.append(IsoCapRow(w.name, training, batch, reports,
                                  stats.read_write_ratio))
    return rows


def _loop_batch_sweep(designs: dict) -> list[IsoCapRow]:
    rows = []
    for training in (True, False):
        for batch in BATCHES:
            stats = traffic.build(alexnet(), batch, training)
            reports = {m: traffic.energy(stats, d)
                       for m, d in designs.items()}
            rows.append(IsoCapRow(stats.workload, training, batch, reports,
                                  stats.read_write_ratio))
    return rows


def _loop_dram_curve() -> dict[float, float]:
    stats = traffic.build(alexnet(), INFER_BATCH, False)
    base = stats.dram_tx(3 * 2**20)
    return {c: 100.0 * (1.0 - stats.dram_tx(c * 2**20) / base)
            for c in DRAM_CAPS_MB}


def _loop_workload_sweep(table) -> list[ScalingRow]:
    """scaling.workload_sweep before the rewire: scalar folds per
    (capacity, stage, memory, workload)."""
    workloads = paper_workloads()
    stage_stats = {
        (training, batch): {name: traffic.build(w, batch, training)
                            for name, w in workloads.items()}
        for training, batch in STAGES}
    rows = []
    for cap in CAPACITIES_MB:
        designs = {m: table.tuned(m, int(cap * 2**20)) for m in MEMS}
        for training, batch in STAGES:
            stats = stage_stats[(training, batch)]
            sram = {name: traffic.energy(stats[name], designs["sram"])
                    for name in workloads}
            for mem in ("stt", "sot"):
                ex, lx, ed = [], [], []
                for name in workloads:
                    r_mem = traffic.energy(stats[name], designs[mem])
                    r_sram = sram[name]
                    ex.append(r_mem.total_j(False) / r_sram.total_j(False))
                    lx.append(r_mem.runtime_s / r_sram.runtime_s)
                    ed.append(r_mem.edp(True) / r_sram.edp(True))
                rows.append(ScalingRow(
                    capacity_mb=cap, mem=mem, training=training,
                    energy_x=statistics.mean(ex),
                    latency_x=statistics.mean(lx),
                    edp_x=statistics.mean(ed),
                    energy_std=statistics.pstdev(ex),
                    edp_std=statistics.pstdev(ed),
                ))
    return rows


def _loop_pass(iso_designs, area_designs, scaling_table):
    return (_loop_stage_rows(iso_designs), _loop_batch_sweep(iso_designs),
            _loop_dram_curve(), _loop_stage_rows(area_designs),
            _loop_workload_sweep(scaling_table))


def _batched_pass():
    return (isocap.analyze(),
            [r for t in (True, False)
             for r in isocap.batch_sweep(alexnet(), t, BATCHES)],
            isoarea.dram_reduction_curve(capacities_mb=DRAM_CAPS_MB),
            isoarea.analyze(),
            scaling.workload_sweep())


# -- parity ----------------------------------------------------------------


def _rel(a: float, b: float) -> float:
    return abs(a - b) / abs(a) if a else abs(b)


def _check_parity(loop_out, batched_out, rel=1e-9) -> float:
    worst = 0.0
    for loop, batched in zip(loop_out, batched_out):
        if isinstance(loop, dict):  # the Fig. 6 curve
            for cap, v in loop.items():
                worst = max(worst, _rel(1.0 + v, 1.0 + batched[cap]))
            continue
        assert len(loop) == len(batched)
        for a, b in zip(loop, batched):
            if isinstance(a, IsoCapRow):
                assert (a.workload, a.batch, a.training) == \
                    (b.workload, b.batch, b.training)
                for m in a.reports:
                    for f in ("runtime_s", "dyn_read_j", "dyn_write_j",
                              "leak_j", "dram_j"):
                        worst = max(worst, _rel(getattr(a.reports[m], f),
                                                getattr(b.reports[m], f)))
            else:
                assert (a.capacity_mb, a.mem, a.training) == \
                    (b.capacity_mb, b.mem, b.training)
                for f in ("energy_x", "latency_x", "edp_x"):
                    worst = max(worst, _rel(getattr(a, f), getattr(b, f)))
    assert worst < rel, worst
    return worst


def run() -> dict:
    # prefetch the circuit layer so both paths time only the workload fold
    iso_designs = isocap.designs_at(CAPACITY_MB)
    area_designs = isoarea.designs().as_dict()
    scaling_table = scaling.tuned_table(CAPACITIES_MB)

    loop_times = []
    for _ in range(2):
        t0 = time.perf_counter()
        loop_out = _loop_pass(iso_designs, area_designs, scaling_table)
        loop_times.append(time.perf_counter() - t0)
    loop_s = min(loop_times)

    # batched: cold (includes jit compile of the fold kernels), then
    # steady-state with the memoized stats/tables/sweep results dropped
    # each rep (the analyses route through sweep.run, whose memo would
    # otherwise short-circuit the fold entirely)
    workload_engine.clear_caches()
    sweep.clear_cache()
    t0 = time.perf_counter()
    batched_out = _batched_pass()
    cold_s = time.perf_counter() - t0

    batched_times = []
    for _ in range(REPS):
        workload_engine.clear_caches()  # keep the jit executable only
        sweep.clear_cache()
        t0 = time.perf_counter()
        batched_out = _batched_pass()
        batched_times.append(time.perf_counter() - t0)
    batched_s = min(batched_times)

    worst = _check_parity(loop_out, batched_out)

    n_scenarios = len(paper_workloads()) * 2 + 2 * len(BATCHES)
    result = dict(
        sweep="fig3-10 workload fold (isocap + batch + dram + isoarea + scaling)",
        n_scenarios=n_scenarios,
        n_designs=3 + 3 + len(CAPACITIES_MB) * len(MEMS),
        loop_s=loop_s,
        batched_cold_s=cold_s,
        batched_s=batched_s,
        speedup_x=loop_s / batched_s,
        speedup_cold_x=loop_s / cold_s,
        parity_max_rel_err=worst,
    )
    os.makedirs(os.path.dirname(JSON_PATH), exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)
    return {"rows": [result],
            "bench": {"loop_s": loop_s, "batched_s": batched_s,
                      "speedup_x": result["speedup_x"],
                      "parity_max_rel_err": worst},
            "derived": (f"loop={loop_s*1e3:.0f}ms,"
                        f"batched={batched_s*1e3:.0f}ms,"
                        f"speedup={result['speedup_x']:.1f}x,"
                        f"parity_err={worst:.2e}")}


if __name__ == "__main__":
    out = run()
    print(out["derived"])

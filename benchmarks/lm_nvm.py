"""Beyond-paper: DeepNVM++ applied to the 10 assigned LM architectures
(DESIGN.md SS2 hardware adaptation).

Workload memory statistics come from the framework's own analytic traffic
model (repro.scenarios, launch/flops.py byte accounting), and the
question becomes the paper's, one platform over: *should the TPU-class
last-level on-chip buffer (VMEM-capacity regime, 16-64 MB) be SRAM or
MRAM for LM training/serving?*

The whole study is one declarative sweep (core/sweep.py): every supported
(arch x shape) cell — train_4k, decode_32k, and long_500k for the
sub-quadratic archs — folded through the EDAP-tuned {sram, stt, sot}
designs at 48 MB on both the TPU-v5e target and the paper's GTX 1080 Ti,
as a single batched [platform] x [arch-shape] x [memory] evaluation.  No
scalar per-cell traffic.energy calls remain.
"""

from __future__ import annotations

from repro import scenarios
from repro.core import sweep
from repro.core.tech import GTX_1080TI, TPU_V5E

PLATFORMS = (TPU_V5E, GTX_1080TI)
QUICK_ARCHS = ("tinyllama-1.1b", "rwkv6-3b", "hymba-1.5b")


def spec(quick: bool = False) -> sweep.SweepSpec:
    return scenarios.lm_sweep_spec(
        platforms=PLATFORMS,
        archs=QUICK_ARCHS if quick else None,
        name="lm-nvm-quick" if quick else "lm-nvm")


def platform_rows(res: sweep.SweepResult, platform_index: int) -> list[dict]:
    """The study's row shape for one platform of a sweep result (shared
    with benchmarks/bench_sweep.py's batched-vs-scalar parity check)."""
    energy = res.metric("energy", include_dram=False)[platform_index]
    edp = res.metric("edp", include_dram=True)[platform_index]
    rw = res.read_write_ratio                           # [s]
    j = {m: res.design_index(m) for m in ("sram", "stt", "sot")}
    pname = res.platform_labels[platform_index]
    rows = []
    for si, (cell, _, _) in enumerate(res.scenario_labels):
        arch, shape = cell.split("/", 1)
        rows.append(dict(
            arch=arch, shape=shape, platform=pname,
            rw_ratio=float(rw[si]),
            stt_energy_red=float(energy[si, j["sram"]]
                                 / energy[si, j["stt"]]),
            sot_energy_red=float(energy[si, j["sram"]]
                                 / energy[si, j["sot"]]),
            stt_edp_red=float(edp[si, j["sram"]] / edp[si, j["stt"]]),
            sot_edp_red=float(edp[si, j["sram"]] / edp[si, j["sot"]]),
        ))
    return rows


def run(quick: bool = False) -> dict:
    res = sweep.run(spec(quick))
    rows = [r for pi in range(len(res.platform_labels))
            for r in platform_rows(res, pi)]
    tpu = [r for r in rows if r["platform"] == TPU_V5E.name]
    mean_stt = sum(r["stt_edp_red"] for r in tpu) / len(tpu)
    mean_sot = sum(r["sot_edp_red"] for r in tpu) / len(tpu)
    n_long = sum(r["shape"] == "long_500k" for r in tpu)
    return {"rows": rows,
            "derived": (f"lm_mean_edp_red_stt={mean_stt:.2f},"
                        f"sot={mean_sot:.2f} @48MB TPU-class buffer,"
                        f"{len(tpu)}cells({n_long}xlong_500k),"
                        f"{len(res.platform_labels)}platforms")}


if __name__ == "__main__":
    print(run()["derived"])

"""Beyond-paper: DeepNVM++ applied to the 10 assigned LM architectures on
the TPU-v5e-class target (DESIGN.md SS2 hardware adaptation).

Workload memory statistics come from the framework's own analytic traffic
model (launch/flops.py byte accounting at 128 B transactions), and the
question becomes the paper's, one platform over: *should the TPU-class
last-level on-chip buffer (VMEM-capacity regime, 16-64 MB) be SRAM or
MRAM for LM training/serving?*
"""

from __future__ import annotations

from repro.core import traffic, tuner
from repro.core.tech import TPU_V5E
from repro.core.traffic import AccessStream, TrafficStats, INF
import repro.configs as configs
from repro.configs.base import SHAPES
from repro.launch import flops as flops_mod

LINE = 128


def lm_traffic(arch: str, shape_name: str) -> TrafficStats:
    """AccessStreams of one step of an (arch x shape) cell, from the same
    analytic model the roofline uses."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    acct = flops_mod.account(cfg, shape)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    d = cfg.d_model
    streams = [
        AccessStream("weights", acct.param_bytes, False, INF),
        AccessStream("activations.r",
                     12.0 * tokens * d * 2.0, False, 4 * tokens * d // 64),
        AccessStream("activations.w",
                     6.0 * tokens * d * 2.0, True, 4 * tokens * d // 64),
        AccessStream("kv.r", acct.kv_read_bytes, False, INF),
        AccessStream("kv.w", acct.kv_write_bytes, True, INF),
        AccessStream("logits", tokens * cfg.vocab * 4.0, True, INF),
    ]
    if shape.kind == "train":
        streams += [
            AccessStream("grads.w", acct.param_bytes, True, INF),
            AccessStream("opt.r", 3.0 * acct.param_bytes, False, INF),
            AccessStream("opt.w", 2.0 * acct.param_bytes, True, INF),
        ]
    return TrafficStats(f"{arch}/{shape_name}", shape.global_batch,
                        shape.kind == "train", tuple(streams),
                        macs_per_batch=acct.flops / 2.0)


def run() -> dict:
    designs = {m: tuner.tuned_design(m, 48) for m in ("sram", "stt", "sot")}
    rows = []
    for arch in configs.all_archs():
        for shape_name in ("train_4k", "decode_32k"):
            cfg = configs.get(arch)
            if shape_name == "long_500k" and not cfg.sub_quadratic:
                continue
            stats = lm_traffic(arch, shape_name)
            reps = {m: traffic.energy(stats, d, TPU_V5E)
                    for m, d in designs.items()}
            rows.append(dict(
                arch=arch, shape=shape_name,
                rw_ratio=stats.read_write_ratio,
                stt_energy_red=reps["sram"].total_j(False)
                / reps["stt"].total_j(False),
                sot_energy_red=reps["sram"].total_j(False)
                / reps["sot"].total_j(False),
                stt_edp_red=reps["sram"].edp(True) / reps["stt"].edp(True),
                sot_edp_red=reps["sram"].edp(True) / reps["sot"].edp(True),
            ))
    mean_sot = sum(r["sot_edp_red"] for r in rows) / len(rows)
    mean_stt = sum(r["stt_edp_red"] for r in rows) / len(rows)
    return {"rows": rows,
            "derived": (f"lm_mean_edp_red_stt={mean_stt:.2f},"
                        f"sot={mean_sot:.2f} @48MB TPU-class buffer")}

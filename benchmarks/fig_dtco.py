"""Beyond-paper: cross-node DTCO sweep on the batched TechNode axis.

The paper's Fig. 9 argument (SRAM leakage makes large LLCs unscalable;
MRAM stays flat) projected across technology nodes: one ``design_table``
call evaluates every (node x memory x capacity x organization) design
point for 16/12/10/7 nm, and one workload fold produces the iso-capacity
EDP/leakage trend per node — the study Mishty & Sadi (2023) assemble
per-node by hand.

Derived headline: SRAM leakage growth from 16 nm to the smallest node and
the widening MRAM leakage/EDP gap at the two ends of the node axis.
"""

from __future__ import annotations

import dataclasses

from repro.core import dtco
from repro.core.workloads import paper_workloads

QUICK_WORKLOADS = 2  # first N paper workloads in --quick mode


def run(quick: bool = False) -> dict:
    nodes = (dtco.NODES[0], dtco.NODES[-1]) if quick else dtco.NODES
    workloads = dict(list(paper_workloads().items())[:QUICK_WORKLOADS]) \
        if quick else None
    rows = dtco.analyze(workloads=workloads, nodes=nodes)
    head = dtco.headline(rows)
    last_nm = rows[-1].feature_nm
    derived = (
        f"sram_leak {head['sram']['leak_w_first']:.2f}W@16nm->"
        f"{head['sram']['leak_w_last']:.2f}W@{last_nm:g}nm"
        f"(x{head['sram']['leak_growth']:.2f}),"
        f"leak_red@{last_nm:g}nm stt={head['stt']['leak_reduction_last']:.1f}"
        f"x,sot={head['sot']['leak_reduction_last']:.1f}x,"
        f"edp_red@{last_nm:g}nm stt={head['stt']['edp_reduction_last']:.2f}"
        f"x,sot={head['sot']['edp_reduction_last']:.2f}x,"
        f"{len(nodes)}nodes")
    return {"rows": [dataclasses.asdict(r) for r in rows],
            "derived": derived}


if __name__ == "__main__":
    print(run()["derived"])

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = the reproduced headline
quantities vs the paper's values) and writes detailed per-row CSVs to
runs/benchmarks/.

Every module run also **appends** one timestamped JSONL entry to
``benchmarks/BENCH_history.jsonl`` (schema ``deepnvm.bench/1``): the
perf-bench modules used to overwrite their ``BENCH_*.json`` with a single
latest sample, so the cross-PR perf trajectory was never recorded.  The
per-module headline metrics come from the optional ``bench`` key of a
module's ``run()`` result; modules without one still get their wall-clock
tracked.

``--only MODULE`` (repeatable, comma-separated) restricts the run — the
CI benchmark-smoke job runs ``--only fig3_4_isocap,lm_nvm,fig_dtco,fig_dtco_isoarea
--quick`` so analysis-layer regressions fail fast.  ``--quick`` is forwarded to
modules whose ``run`` accepts a ``quick`` keyword (reduced reps / arch
sets); the rest run unchanged.
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import time
from datetime import datetime, timezone

from repro.core.report import write_csv

HISTORY_PATH = "benchmarks/BENCH_history.jsonl"
HISTORY_SCHEMA = "deepnvm.bench/1"

MODULES = (
    "table1_bitcell",
    "table2_cache",
    "fig3_4_isocap",
    "fig5_batch",
    "fig6_dram",
    "fig7_8_isoarea",
    "fig9_10_scaling",
    "fig_dtco",
    "fig_dtco_isoarea",
    "lm_nvm",
    "bench_engine",
    "bench_workload_engine",
    "bench_sweep",
    "bench_shard",
    "bench_serve",
    "bench_analysis",
    "bench_inverse",
    "fig_sensitivity",
)


def append_history(name: str, us_per_call: float, result: dict,
                   quick: bool, path: str = HISTORY_PATH) -> dict:
    """One appended trajectory entry per module run.  The schema is
    stable: fixed envelope keys, module-specific numbers confined to
    ``metrics`` (the module's ``bench`` dict)."""
    entry = {
        "schema": HISTORY_SCHEMA,
        "ts": datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "module": name,
        "quick": quick,
        "us_per_call": round(us_per_call, 1),
        "metrics": result.get("bench", {}),
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def select(only: list[str] | None) -> tuple[str, ...]:
    if not only:
        return MODULES
    wanted = [n for arg in only for n in arg.split(",") if n]
    unknown = sorted(set(wanted) - set(MODULES))
    if unknown:
        raise SystemExit(f"unknown benchmark module(s): {', '.join(unknown)}"
                         f" (choose from: {', '.join(MODULES)})")
    return tuple(n for n in MODULES if n in wanted)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", action="append", metavar="MODULE",
                    help="run only this module (repeatable, comma-separated)")
    ap.add_argument("--quick", action="store_true",
                    help="reduced work where a module supports it")
    args = ap.parse_args(argv)
    names = select(args.only)

    print("name,us_per_call,derived")
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        kwargs = {"quick": True} if args.quick and \
            "quick" in inspect.signature(mod.run).parameters else {}
        t0 = time.perf_counter()
        result = mod.run(**kwargs)
        dt_us = (time.perf_counter() - t0) * 1e6
        derived = result.get("derived", "")
        print(f'{name},{dt_us:.0f},"{derived}"')
        append_history(name, dt_us, result, args.quick)
        if result.get("rows"):
            write_csv(f"runs/benchmarks/{name}.csv", result["rows"])
        if result.get("ppa"):
            write_csv(f"runs/benchmarks/{name}_ppa.csv", result["ppa"])


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived = the reproduced headline
quantities vs the paper's values) and writes detailed per-row CSVs to
runs/benchmarks/.
"""

from __future__ import annotations

import importlib
import time

from repro.core.report import write_csv

MODULES = (
    "table1_bitcell",
    "table2_cache",
    "fig3_4_isocap",
    "fig5_batch",
    "fig6_dram",
    "fig7_8_isoarea",
    "fig9_10_scaling",
    "lm_nvm",
    "bench_engine",
    "bench_workload_engine",
)


def main() -> None:
    print("name,us_per_call,derived")
    for name in MODULES:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.perf_counter()
        result = mod.run()
        dt_us = (time.perf_counter() - t0) * 1e6
        derived = result.get("derived", "")
        print(f'{name},{dt_us:.0f},"{derived}"')
        if result.get("rows"):
            write_csv(f"runs/benchmarks/{name}.csv", result["rows"])
        if result.get("ppa"):
            write_csv(f"runs/benchmarks/{name}_ppa.csv", result["ppa"])


if __name__ == "__main__":
    main()

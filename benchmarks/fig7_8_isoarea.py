"""Paper Figs. 7/8: iso-area energy and EDP (with/without DRAM terms).

Rows are views into one batched [workload-stage] x [memory] fold at the
iso-area design corners (isoarea.analyze)."""

from __future__ import annotations

from repro.core import isoarea
from repro.core.calibration import PAPER_CLAIMS


def run() -> dict:
    d = isoarea.designs()
    rows_ = isoarea.analyze()
    summary = isoarea.summary(rows_)
    rows = []
    for r in rows_:
        for mem in ("stt", "sot"):
            rows.append(dict(
                workload=r.workload,
                stage="train" if r.training else "infer",
                mem=mem,
                dyn_x=r.norm("dyn", mem),
                leak_x=r.norm("leak", mem),
                edp_x_no_dram=r.norm("edp", mem, include_dram=False),
                edp_x_with_dram=r.norm("edp", mem, include_dram=True),
            ))
    claims = PAPER_CLAIMS
    checks = {
        "stt_capacity_mb": (d.stt_capacity_mb, 7),
        "sot_capacity_mb": (d.sot_capacity_mb, 10),
        "stt_dyn_x": (summary["stt"]["dyn_energy_x"],
                      claims["isoarea_dyn_energy_x"]["stt"]),
        "sot_dyn_x": (summary["sot"]["dyn_energy_x"],
                      claims["isoarea_dyn_energy_x"]["sot"]),
        "stt_leak_red": (summary["stt"]["leak_reduction"],
                         claims["isoarea_leak_reduction"]["stt"]),
        "sot_leak_red": (summary["sot"]["leak_reduction"],
                         claims["isoarea_leak_reduction"]["sot"]),
        "stt_edp_no_dram": (summary["stt"]["edp_reduction_no_dram"],
                            claims["isoarea_edp_reduction_no_dram"]["stt"]),
        "sot_edp_no_dram": (summary["sot"]["edp_reduction_no_dram"],
                            claims["isoarea_edp_reduction_no_dram"]["sot"]),
        "stt_edp_with_dram": (summary["stt"]["edp_reduction_with_dram"],
                              claims["isoarea_edp_reduction_with_dram"]["stt"]),
        "sot_edp_with_dram": (summary["sot"]["edp_reduction_with_dram"],
                              claims["isoarea_edp_reduction_with_dram"]["sot"]),
    }
    return {"rows": rows, "summary": summary, "claims": checks,
            "derived": ",".join(f"{k}={m:.2f}/(paper {p})"
                                for k, (m, p) in checks.items())}

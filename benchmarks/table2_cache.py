"""Paper Table II: EDAP-tuned cache designs (Algorithm 1 + NVSim layer)."""

from __future__ import annotations

from repro.core import tuner
from repro.core.calibration import TABLE2


def run() -> dict:
    designs = tuner.table2()
    rows, errs, isoarea_errs = [], [], []
    for col, d in designs.items():
        ref = TABLE2[col]
        row = dict(column=col, capacity_mb=d.capacity_mb,
                   read_lat_ns=d.read_latency_s * 1e9,
                   write_lat_ns=d.write_latency_s * 1e9,
                   read_e_nj=d.read_energy_j * 1e9,
                   write_e_nj=d.write_energy_j * 1e9,
                   leak_mw=d.leakage_w * 1e3,
                   area_mm2=d.area_mm2,
                   org=str(d.org))
        rows.append(row)
        pairs = ((d.capacity_mb, ref["cap"]),
                 (d.read_latency_s * 1e9, ref["rlat"]),
                 (d.write_latency_s * 1e9, ref["wlat"]),
                 (d.read_energy_j * 1e9, ref["re"]),
                 (d.write_energy_j * 1e9, ref["we"]),
                 (d.leakage_w * 1e3, ref["leak"]),
                 (d.area_mm2, ref["area"]))
        rel = [abs(m - r) / r for m, r in pairs]
        (isoarea_errs if "isoarea" in col else errs).extend(rel)
    return {"rows": rows,
            "anchor_max_rel_err": max(errs),
            "isoarea_max_rel_err": max(isoarea_errs),
            "derived": (f"3MB_anchor_err={max(errs):.4f},"
                        f"isoarea_err={max(isoarea_errs):.4f}")}

"""Batched-engine benchmark: the full Fig. 9/10 sweep, loop vs batched.

Times the complete ``scaling.ppa_sweep`` + ``scaling.workload_sweep`` pass
two ways:

  loop     the seed implementation — one scalar ``CacheModel.evaluate`` per
           design point (tuner.tune_loop), tuned designs and workload
           traffic re-derived per capacity, exactly as the pre-engine code
           did;
  batched  the engine path — one jitted evaluation of the whole
           (tech x capacity x organization) tensor shared by both sweeps.

Cross-checks that the two paths produce the same rows, then writes the
timing comparison to benchmarks/BENCH_engine.json (run from the repo
root, like the rest of benchmarks/).
"""

from __future__ import annotations

import json
import os
import statistics
import time

from repro.core import engine, scaling, traffic, tuner
from repro.core.cachemodel import CacheModel
from repro.core.isocap import INFER_BATCH, TRAIN_BATCH, MEMS
from repro.core.scaling import CAPACITIES_MB, PPARow, ScalingRow
from repro.core.tech import TECH_16NM, scaled_node
from repro.core.workloads import paper_workloads

JSON_PATH = "benchmarks/BENCH_engine.json"  # version-controlled record
REPS = 5


def _loop_ppa_sweep(capacities_mb=CAPACITIES_MB) -> list[PPARow]:
    """scaling.ppa_sweep as the seed wrote it: a fresh scalar tune per
    (capacity, technology)."""
    rows = []
    for cap in capacities_mb:
        for mem in MEMS:
            d = tuner.tune_loop(CacheModel(mem), int(cap * 2**20))
            rows.append(PPARow(
                capacity_mb=cap, mem=mem,
                read_latency_ns=d.read_latency_s * 1e9,
                write_latency_ns=d.write_latency_s * 1e9,
                read_energy_nj=d.read_energy_j * 1e9,
                write_energy_nj=d.write_energy_j * 1e9,
                leakage_w=d.leakage_w,
                area_mm2=d.area_mm2,
            ))
    return rows


def _loop_workload_sweep(capacities_mb=CAPACITIES_MB) -> list[ScalingRow]:
    """scaling.workload_sweep as the seed wrote it: tuned designs re-derived
    per capacity and traffic statistics rebuilt per (capacity, stage)."""
    workloads = paper_workloads()
    rows = []
    for cap in capacities_mb:
        designs = {m: tuner.tune_loop(CacheModel(m), int(cap * 2**20))
                   for m in MEMS}
        for training, batch in ((False, INFER_BATCH), (True, TRAIN_BATCH)):
            stats = {name: traffic.build(w, batch, training)
                     for name, w in workloads.items()}
            for mem in ("stt", "sot"):
                ex, lx, ed = [], [], []
                for name in workloads:
                    r_mem = traffic.energy(stats[name], designs[mem])
                    r_sram = traffic.energy(stats[name], designs["sram"])
                    ex.append(r_mem.total_j(False) / r_sram.total_j(False))
                    lx.append(r_mem.runtime_s / r_sram.runtime_s)
                    ed.append(r_mem.edp(True) / r_sram.edp(True))
                rows.append(ScalingRow(
                    capacity_mb=cap, mem=mem, training=training,
                    energy_x=statistics.mean(ex),
                    latency_x=statistics.mean(lx),
                    edp_x=statistics.mean(ed),
                    energy_std=statistics.pstdev(ex),
                    edp_std=statistics.pstdev(ed),
                ))
    return rows


def _clear_engine_caches() -> None:
    engine.design_table.cache_clear()
    tuner._tuned_design_cached.cache_clear()


def _node_retrace_count() -> int:
    """How many extra jit traces a NEW node value costs at fixed shapes.

    The node/periphery parameters are runtime tensor rows of the
    ``[n, NODE_FIELDS]`` matrix, so after the anchor trace and the
    scaled-node trace exist for a shape, sweeping any further node must
    not retrace — this is the property that keeps the cross-node DTCO
    sweeps one compile, and it is the one a careless "bake the node into
    the trace as Python floats" refactor would silently break."""
    caps = (3 * 2**20,)
    # Prime both traces for this shape: the anchor-periphery trace and
    # the runtime-periphery trace.
    engine.sweep(caps, nodes=TECH_16NM)
    engine.sweep(caps, nodes=scaled_node(13e-9, name="bench-13nm"))
    base = engine.ppa_fn._cache_size()
    for nm in (11.0, 9.0, 8.0):
        engine.sweep(caps, nodes=scaled_node(nm * 1e-9, name=f"bench-{nm:g}nm"))
    return engine.ppa_fn._cache_size() - base


def _check_parity(loop_rows, batched_rows, rel=1e-9) -> float:
    assert len(loop_rows) == len(batched_rows)
    worst = 0.0
    for a, b in zip(loop_rows, batched_rows):
        assert (a.capacity_mb, a.mem) == (b.capacity_mb, b.mem)
        for f, x in a.__dict__.items():
            y = getattr(b, f)
            if isinstance(x, float) and x:
                err = abs(x - y) / abs(x)
                assert err < rel, (f, a, b)
                worst = max(worst, err)
    return worst


def run() -> dict:
    # -- loop (seed) path --------------------------------------------------
    loop_times = []
    for _ in range(2):
        t0 = time.perf_counter()
        loop_ppa = _loop_ppa_sweep()
        loop_wl = _loop_workload_sweep()
        loop_times.append(time.perf_counter() - t0)
    loop_s = min(loop_times)

    # -- batched path: cold (includes jit compile), then steady-state ------
    _clear_engine_caches()
    t0 = time.perf_counter()
    batched_ppa = scaling.ppa_sweep()
    batched_wl = scaling.workload_sweep()
    cold_s = time.perf_counter() - t0

    batched_times = []
    for _ in range(REPS):
        _clear_engine_caches()   # keep the jit executable, redo the sweep
        t0 = time.perf_counter()
        batched_ppa = scaling.ppa_sweep()
        batched_wl = scaling.workload_sweep()
        batched_times.append(time.perf_counter() - t0)
    batched_s = min(batched_times)

    worst = max(_check_parity(loop_ppa, batched_ppa),
                _check_parity(loop_wl, batched_wl))

    node_retraces = _node_retrace_count()
    assert node_retraces == 0, \
        f"new node values must not recompile the kernel ({node_retraces})"

    result = dict(
        sweep="scaling.ppa_sweep + scaling.workload_sweep",
        capacities_mb=list(CAPACITIES_MB),
        n_design_points=len(engine.ORGS) * len(CAPACITIES_MB) * len(MEMS),
        loop_s=loop_s,
        batched_cold_s=cold_s,
        batched_s=batched_s,
        speedup_x=loop_s / batched_s,
        speedup_cold_x=loop_s / cold_s,
        parity_max_rel_err=worst,
        node_retraces=node_retraces,
    )
    os.makedirs(os.path.dirname(JSON_PATH), exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)
    return {"rows": [result],
            "bench": {"loop_s": loop_s, "batched_s": batched_s,
                      "speedup_x": result["speedup_x"],
                      "parity_max_rel_err": worst,
                      "node_retraces": node_retraces},
            "derived": (f"loop={loop_s*1e3:.0f}ms,"
                        f"batched={batched_s*1e3:.0f}ms,"
                        f"speedup={result['speedup_x']:.1f}x,"
                        f"parity_err={worst:.2e},"
                        f"node_retraces={node_retraces}")}


if __name__ == "__main__":
    out = run()
    print(out["derived"])

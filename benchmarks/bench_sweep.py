"""Sweep-pipeline benchmark: the batched LM fold vs the scalar loop it
replaced, plus end-to-end wall-clock of the unified sweep pipeline.

Two comparisons, recorded in benchmarks/BENCH_sweep.json:

  lm fold   the LM study's [platform] x [arch-shape] x [memory]
            evaluation, both ways over the identical scenario and
            platform set: ``loop`` is the pre-sweep lm_nvm implementation
            (statistics rebuilt per cell, one ``traffic.energy`` call per
            (platform, cell, memory)), ``batched`` is the SweepSpec
            lowering (one workload-engine kernel for everything).  Tuned
            designs (the circuit layer) are prefetched for both, so the
            comparison isolates the fold the refactor replaced.

  end-to-end  every sweep-backed analysis — isocap rows + batch sweep,
            the Fig. 6 DRAM curve, isoarea rows, the scaling sweep, and
            the two-platform LM study — cold (first call, jit compiles
            included) and steady-state.  Steady-state drops the
            architecture-layer memos (scenario stats, fold tables, sweep
            results) each rep but keeps the circuit layer warm (design
            tables and Algorithm-1 tunings stay memoized, as in a
            long-lived process — bench_engine.py times that layer).
"""

from __future__ import annotations

import functools
import json
import os
import time

from benchmarks import lm_nvm
from repro import scenarios
from repro.core import isoarea, isocap, scaling, sweep, traffic
from repro.core.workloads import alexnet
from repro.core import workload_engine

JSON_PATH = "benchmarks/BENCH_sweep.json"
REPS = 7


def _clear_pipeline_caches() -> None:
    """Drop every architecture-layer memo (stats, fold tables, sweep
    results, LM scenarios) so a rep re-runs the workload side of the
    pipeline; circuit-layer design tables stay warm by design."""
    workload_engine.clear_caches()
    sweep.clear_cache()
    scenarios.lm_traffic.cache_clear()


# -- the LM fold, both ways -------------------------------------------------


@functools.lru_cache(maxsize=None)
def _lm_cells() -> tuple[tuple[str, str], ...]:
    return tuple(tuple(s.workload.split("/", 1))
                 for s in scenarios.lm_scenarios())


def _loop_lm_rows(designs: dict) -> list[dict]:
    """The pre-sweep lm_nvm loop: statistics rebuilt per cell and one
    scalar traffic.energy call per (platform, cell, memory), over the
    same scenario and platform set as the batched study."""
    rows = []
    for platform in lm_nvm.PLATFORMS:
        for arch, shape in _lm_cells():
            stats = scenarios.lm_traffic.__wrapped__(arch, shape)
            reps = {m: traffic.energy(stats, d, platform)
                    for m, d in designs.items()}
            rows.append(dict(
                arch=arch, shape=shape, platform=platform.name,
                rw_ratio=stats.read_write_ratio,
                stt_energy_red=reps["sram"].total_j(False)
                / reps["stt"].total_j(False),
                sot_energy_red=reps["sram"].total_j(False)
                / reps["sot"].total_j(False),
                stt_edp_red=reps["sram"].edp(True) / reps["stt"].edp(True),
                sot_edp_red=reps["sram"].edp(True) / reps["sot"].edp(True),
            ))
    return rows


def _batched_lm_rows() -> list[dict]:
    res = sweep.run(lm_nvm.spec())   # both platforms, one kernel
    return [r for pi in range(len(res.platform_labels))
            for r in lm_nvm.platform_rows(res, pi)]


def _check_parity(loop_rows, batched_rows, rel=1e-9) -> float:
    assert len(loop_rows) == len(batched_rows)
    worst = 0.0
    for a, b in zip(loop_rows, batched_rows):
        assert (a["arch"], a["shape"], a["platform"]) == \
            (b["arch"], b["shape"], b["platform"])
        for f in ("rw_ratio", "stt_energy_red", "sot_energy_red",
                  "stt_edp_red", "sot_edp_red"):
            worst = max(worst, abs(a[f] - b[f]) / abs(a[f]))
    assert worst < rel, worst
    return worst


# -- the end-to-end pipeline ------------------------------------------------


def _pipeline_pass():
    return (isocap.analyze(),
            isocap.batch_sweep(alexnet(), True),
            isoarea.dram_reduction_curve(),
            isoarea.analyze(),
            scaling.workload_sweep(),
            lm_nvm.run())


def run(quick: bool = False) -> dict:
    reps = 2 if quick else REPS

    # prefetch the circuit layer: both LM paths read the same tuned designs
    designs = isocap.designs_at(scenarios.LM_CAPACITY_MB)

    loop_times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        loop_rows = _loop_lm_rows(designs)
        loop_times.append(time.perf_counter() - t0)
    lm_loop_s = min(loop_times)

    # batched: cold (includes the fold kernel's jit compile), then
    # steady-state with every memoized layer above jit dropped per rep
    _clear_pipeline_caches()
    t0 = time.perf_counter()
    batched_rows = _batched_lm_rows()
    lm_cold_s = time.perf_counter() - t0

    batched_times = []
    for _ in range(reps):
        _clear_pipeline_caches()
        t0 = time.perf_counter()
        batched_rows = _batched_lm_rows()
        batched_times.append(time.perf_counter() - t0)
    lm_batched_s = min(batched_times)

    worst = _check_parity(loop_rows, batched_rows)

    # end-to-end: all sweep-backed analyses
    _clear_pipeline_caches()
    t0 = time.perf_counter()
    _pipeline_pass()
    e2e_cold_s = time.perf_counter() - t0
    e2e_times = []
    for _ in range(reps):
        _clear_pipeline_caches()
        t0 = time.perf_counter()
        _pipeline_pass()
        e2e_times.append(time.perf_counter() - t0)
    e2e_s = min(e2e_times)

    result = dict(
        sweep="unified sweep pipeline (LM fold + all analyses)",
        n_lm_cells=len(_lm_cells()),
        n_platforms=len(lm_nvm.PLATFORMS),
        lm_loop_s=lm_loop_s,
        lm_batched_cold_s=lm_cold_s,
        lm_batched_s=lm_batched_s,
        lm_speedup_x=lm_loop_s / lm_batched_s,
        e2e_cold_s=e2e_cold_s,
        e2e_s=e2e_s,
        parity_max_rel_err=worst,
    )
    os.makedirs(os.path.dirname(JSON_PATH), exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)
    return {"rows": [result],
            "bench": {"lm_loop_s": lm_loop_s, "lm_batched_s": lm_batched_s,
                      "lm_speedup_x": result["lm_speedup_x"],
                      "e2e_s": e2e_s,
                      "parity_max_rel_err": worst},
            "derived": (f"lm_loop={lm_loop_s*1e3:.1f}ms,"
                        f"lm_batched={lm_batched_s*1e3:.1f}ms,"
                        f"speedup={result['lm_speedup_x']:.1f}x,"
                        f"e2e={e2e_s*1e3:.0f}ms,"
                        f"parity_err={worst:.2e}")}


if __name__ == "__main__":
    print(run()["derived"])

"""Paper Table I: bitcell characterization (circuit layer)."""

from __future__ import annotations

from repro.core import bitcell
from repro.core.calibration import TABLE1


def run() -> dict:
    cells = bitcell.table1()
    rows, errs = [], []
    for name in ("stt", "sot"):
        c = cells[name]
        ref = TABLE1[name]
        rows.append(dict(
            mem=name,
            sense_lat_ps=c.sense_latency_s * 1e12,
            sense_e_pj=c.sense_energy_j * 1e12,
            wlat_set_ps=c.write_latency_set_s * 1e12,
            wlat_reset_ps=c.write_latency_reset_s * 1e12,
            we_set_pj=c.write_energy_set_j * 1e12,
            we_reset_pj=c.write_energy_reset_j * 1e12,
            fins_read=c.fins_read, fins_write=c.fins_write,
            area_norm=c.area_norm,
        ))
        for model_v, ref_v in (
                (c.sense_latency_s, ref["sense_lat"]),
                (c.sense_energy_j, ref["sense_e"]),
                (c.write_latency_set_s, ref["wlat_set"]),
                (c.write_latency_reset_s, ref["wlat_reset"]),
                (c.write_energy_set_j, ref["we_set"]),
                (c.write_energy_reset_j, ref["we_reset"]),
                (c.area_norm, ref["area"])):
            errs.append(abs(model_v - ref_v) / ref_v)
    return {"rows": rows, "max_rel_err": max(errs),
            "derived": f"max_rel_err={max(errs):.4f}"}

"""Paper Figs. 9/10: scalability analysis (PPA + workload sweeps).

Both sweeps are pairs of batched computations: the circuit engine's
design table and the workload engine's [workload x stage] x [memory x
capacity] fold (scaling.workload_sweep)."""

from __future__ import annotations

from repro.core import scaling
from repro.core.calibration import PAPER_CLAIMS


def run() -> dict:
    ppa = [r.__dict__ for r in scaling.ppa_sweep()]
    wl = scaling.workload_sweep()
    head = scaling.headline(wl)
    rows = [r.__dict__ for r in wl]
    claims = PAPER_CLAIMS
    checks = {
        "stt_energy_red_max": (head["stt"]["energy_reduction_max"],
                               claims["scaling_energy_reduction_max"]["stt"]),
        "sot_energy_red_max": (head["sot"]["energy_reduction_max"],
                               claims["scaling_energy_reduction_max"]["sot"]),
        "stt_latency_red_max": (head["stt"]["latency_reduction_max"],
                                claims["scaling_latency_reduction_max"]["stt"]),
        "sot_latency_red_max": (head["sot"]["latency_reduction_max"],
                                claims["scaling_latency_reduction_max"]["sot"]),
        "stt_edp_red_max": (head["stt"]["edp_reduction_max"],
                            claims["scaling_edp_reduction_max"]["stt"]),
        "sot_edp_red_max": (head["sot"]["edp_reduction_max"],
                            claims["scaling_edp_reduction_max"]["sot"]),
    }
    return {"rows": rows, "ppa": ppa, "claims": checks,
            "derived": ",".join(f"{k}={m:.1f}/(paper {p})"
                                for k, (m, p) in checks.items())}

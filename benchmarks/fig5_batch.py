"""Paper Fig. 5: batch-size impact on EDP (AlexNet, iso-capacity).

The batch axis is one scenario dimension of a single batched
workload-engine fold (isocap.batch_sweep)."""

from __future__ import annotations

from repro.core import isocap
from repro.core.calibration import PAPER_CLAIMS
from repro.core.workloads import alexnet


def run() -> dict:
    rows = []
    spans = {}
    for training in (True, False):
        sweep = isocap.batch_sweep(alexnet(), training)
        for r in sweep:
            for mem in ("stt", "sot"):
                rows.append(dict(stage="train" if training else "infer",
                                 batch=r.batch, mem=mem,
                                 edp_reduction=1 / r.norm("edp", mem, True),
                                 rw_ratio=r.read_write_ratio))
        for mem in ("stt", "sot"):
            reds = [1 / r.norm("edp", mem, True) for r in sweep]
            spans[f"{mem}_{'train' if training else 'infer'}"] = (
                min(reds), max(reds))
    claims = {
        "stt_train": PAPER_CLAIMS["batch_sweep_train_edp"]["stt"],
        "sot_train": PAPER_CLAIMS["batch_sweep_train_edp"]["sot"],
        "stt_infer": PAPER_CLAIMS["batch_sweep_infer_edp"]["stt"],
        "sot_infer": PAPER_CLAIMS["batch_sweep_infer_edp"]["sot"],
    }
    return {"rows": rows, "spans": spans, "claims": claims,
            "derived": ",".join(
                f"{k}=({v[0]:.1f}..{v[1]:.1f})/(paper {claims[k]})"
                for k, v in spans.items())}

"""Static-analysis benchmark: analyzer runtime and finding counts over
``src/repro``, recorded in benchmarks/BENCH_analysis.json.

Two things are worth tracking across PRs:

  runtime   wall time of a full four-rule pass over the source tree.
            The analyzer runs in the CI critical path (the
            ``static-analysis`` job gates merges), so it has to stay
            cheap — a few seconds, not a linter-framework minute.

  counts    files analyzed and per-rule finding totals, split into
            active / suppressed / baselined.  The strict gate already
            enforces active == 0; the history row records how much
            accepted debt (baseline + suppressions) that gate is
            carrying, so growth is visible in BENCH_history.jsonl
            rather than hidden in the baseline file.
"""

from __future__ import annotations

import json
import os
import time

from repro.analysis import common, driver

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = "benchmarks/BENCH_analysis.json"
REPS = 3


def run(quick: bool = False) -> dict:
    target = os.path.join(ROOT, "src", "repro")
    baseline = common.load_baseline(os.path.join(
        ROOT, common.BASELINE_DEFAULT))

    reps = 1 if quick else REPS
    times_s = []
    result = None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = driver.run_paths([target], baseline=baseline)
        times_s.append(time.perf_counter() - t0)
    best_s = min(times_s)

    by_rule = {rule: 0 for rule in driver.CHECKS}
    for f in result.findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1

    row = {
        "files": result.files,
        "run_s": round(best_s, 3),
        "us_per_file": round(best_s / max(result.files, 1) * 1e6, 1),
        "active": len(result.active),
        "suppressed": result.suppressed,
        "baselined": result.baselined,
        **{f"findings_{r.lower()}": n for r, n in sorted(by_rule.items())},
    }
    os.makedirs(os.path.dirname(JSON_PATH), exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(row, f, indent=2)

    return {
        "rows": [row],
        "bench": {
            "files": row["files"],
            "run_s": row["run_s"],
            "us_per_file": row["us_per_file"],
            "active_findings": row["active"],
            "suppressed": row["suppressed"],
            "baselined": row["baselined"],
        },
        "derived": (f"{row['files']} files in {row['run_s']:.2f}s, "
                    f"active={row['active']}, "
                    f"baselined={row['baselined']}"),
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=2))

"""Beyond-paper: cross-node iso-AREA study on the node-aware circuit stack.

The paper's iso-area argument (spend the MRAM density advantage on
capacity, win on DRAM traffic) taken across technology nodes: at every
node the SRAM area budget is re-derived from that node's EDAP-tuned
designs and buys that node's largest-fitting MRAM capacities
(``isoarea.corners(node=...)``), which only carries signal now that the
MTJ devices, bitcells, and periphery all project per node
(tech.*_SCALING_EXPONENTS) — the deliverable of the node-aware refactor.

Derived headline: per-flavor iso-area capacity at both ends of the node
axis and the widening leakage/EDP gap against same-node SRAM.
"""

from __future__ import annotations

import dataclasses

from repro.core import dtco
from repro.core.workloads import paper_workloads

QUICK_WORKLOADS = 2  # first N paper workloads in --quick mode


def run(quick: bool = False) -> dict:
    nodes = (dtco.NODES[0], dtco.NODES[-1]) if quick else dtco.NODES
    workloads = dict(list(paper_workloads().items())[:QUICK_WORKLOADS]) \
        if quick else None
    rows = dtco.isoarea_analyze(workloads=workloads, nodes=nodes)
    head = dtco.isoarea_headline(rows)
    last_nm = rows[-1].feature_nm
    derived = (
        f"isoarea_cap stt={head['stt']['capacity_mb_first']:g}MB@16nm->"
        f"{head['stt']['capacity_mb_last']:g}MB@{last_nm:g}nm,"
        f"sot={head['sot']['capacity_mb_first']:g}MB->"
        f"{head['sot']['capacity_mb_last']:g}MB,"
        f"edp_red@{last_nm:g}nm stt={head['stt']['edp_reduction_last']:.2f}"
        f"x,sot={head['sot']['edp_reduction_last']:.2f}x,"
        f"sram_leak x{head['sram']['leak_growth']:.2f},"
        f"{len(nodes)}nodes")
    bench = {
        "stt_cap_mb_last": head["stt"]["capacity_mb_last"],
        "sot_cap_mb_last": head["sot"]["capacity_mb_last"],
        "stt_edp_reduction_last": head["stt"]["edp_reduction_last"],
        "sot_edp_reduction_last": head["sot"]["edp_reduction_last"],
        "sram_leak_growth": head["sram"]["leak_growth"],
    }
    return {"rows": [dataclasses.asdict(r) for r in rows],
            "derived": derived, "bench": bench}


if __name__ == "__main__":
    print(run()["derived"])

"""Inverse-design benchmark: recovery, solver throughput, off-grid gain,
standard-path parity — recorded in benchmarks/BENCH_inverse.json.

Four measurements on the shipped ``specs/inverse_isocap.json`` problem:

  recovery    the hardened center evaluation must select the same
              (mem, capacity, node, org) corner as the grid argmin
              (softmin -> argmin consistency on the golden spec); the
              full run checks dtco_isoarea's 12-corner grid too;

  solve       wall time of the multi-start projected-Adam solve and the
              resulting Adam-step throughput (starts x iters / s — the
              batched-vmap economics of the driver);

  gain        the off-grid EDP improvement over the best grid corner at
              the same iso-area budget (the paper's grid can only pick
              corners; the gradient path lands between them);

  parity      |relaxed optimum - standard-path re-evaluation| relative
              error, asserted <= 1e-12 (every reported number is backed
              by the non-relaxed engine).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro import inverse
from repro.core.sweep import SymbolicSweepSpec
from repro.inverse import relax

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = "benchmarks/BENCH_inverse.json"


def _converged_at(trajectory: tuple[float, ...], rel_tol: float = 1e-3,
                  ) -> int:
    """First iteration whose loss is within rel_tol of the final loss."""
    final = trajectory[-1]
    span = max(abs(final), 1e-12)
    for i, v in enumerate(trajectory):
        if abs(v - final) / span <= rel_tol:
            return i + 1
    return len(trajectory)


def _check_recovery(spec_path: str) -> dict:
    prob = inverse.InverseProblem(
        sweep=SymbolicSweepSpec.load(spec_path), objective="edp")
    grid = inverse.grid_argmin(prob)
    rec = inverse.recover_corner(prob)
    assert rec["corner"] == grid["corner"], (rec["corner"], grid["corner"])
    err = abs(rec["value"] - grid["value"]) / grid["value"]
    assert err <= 1e-12, err
    return {"corner": grid["corner"], "rel_err": err}


def run(quick: bool = False) -> dict:
    prob = inverse.InverseProblem.load(
        os.path.join(ROOT, "specs", "inverse_isocap.json"))
    if quick:
        prob = dataclasses.replace(prob, starts=1, iters=40)

    recovery = {"isocap": _check_recovery(
        os.path.join(ROOT, "specs", "isocap.json"))}
    if not quick:
        recovery["dtco_isoarea"] = _check_recovery(
            os.path.join(ROOT, "specs", "dtco_isoarea.json"))

    t0 = time.perf_counter()
    res = inverse.solve(prob)
    solve_s = time.perf_counter() - t0
    assert res.parity_rel_err <= 1e-12, res.parity_rel_err
    assert res.best_value < res.grid_best_value
    assert res.area_mm2 <= res.area_budget_mm2 * (1.0 + 1e-9)

    adam_steps = prob.starts * prob.iters
    converged_at = _converged_at(res.trajectory)
    leaves_moved = sum(
        1 for g in relax.lower(prob).groups
        for f, c in zip(inverse.LEAF_FIELDS, g.centers)
        if abs(res.leaves[g.key][f] - c) / c > 1e-3)

    result = dict(
        inverse="gradient-based inverse design (specs/inverse_isocap.json)",
        starts=prob.starts,
        iters=prob.iters,
        solve_s=solve_s,
        adam_steps_s=adam_steps / solve_s,
        converged_at_iter=converged_at,
        best_value=res.best_value,
        grid_best_value=res.grid_best_value,
        gain_vs_grid_pct=100.0 * res.gain_vs_grid,
        area_mm2=res.area_mm2,
        area_budget_mm2=res.area_budget_mm2,
        parity_rel_err=res.parity_rel_err,
        leaves_moved=leaves_moved,
        corner=res.corner,
        active_constraints=res.active_constraints,
        recovery={k: v["rel_err"] for k, v in recovery.items()},
    )
    os.makedirs(os.path.dirname(JSON_PATH), exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)

    rows = [{"metric": k, "value": v if np.isscalar(v) else json.dumps(v)}
            for k, v in result.items()]
    return {"rows": rows,
            "bench": {"solve_s": solve_s,
                      "adam_steps_s": result["adam_steps_s"],
                      "gain_vs_grid_pct": result["gain_vs_grid_pct"],
                      "parity_rel_err": res.parity_rel_err,
                      "converged_at_iter": converged_at},
            "derived": (f"gain={result['gain_vs_grid_pct']:+.1f}%,"
                        f"parity={res.parity_rel_err:.1e},"
                        f"solve={solve_s:.1f}s,"
                        f"steps/s={result['adam_steps_s']:.0f},"
                        f"recovered={','.join(recovery)}")}


if __name__ == "__main__":
    print(run()["derived"])

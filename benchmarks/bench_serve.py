"""Sweep-service benchmark: cold start vs warmup, and coalesced vs
serial throughput, recorded in benchmarks/BENCH_serve.json.

Three measurements:

  cold start  first-request latency of a fresh process (subprocess, jax
              import excluded — the same methodology as BENCH_sweep.json's
              ``e2e_cold_s``) against a process that called
              ``SweepService.warmup`` on the same spec first.  The warmed
              service answers its first request at warm-dispatch cost
              because every compile (bitcell characterization,
              calibration, PPA traces, the bucketed fold) already
              happened before traffic arrived.  A second warmed run
              reusing a JAX persistent-compilation-cache directory
              measures how much of the warmup itself survives restarts.

  throughput  8 concurrent compatible golden-derived requests (isocap
              scenario slices x capacity variants) through the coalescing
              service vs the same requests answered one-at-a-time with
              coalescing disabled.  Identical per-request cells both
              ways; the coalesced path evaluates ONE superset fold per
              window instead of eight.

  parity      every coalesced response's rows vs its individual
              ``sweep.run()`` (worst relative error, asserted <= 1e-12).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import threading
import time

from repro.core import sweep
from repro.core.sweep import SymbolicSweepSpec
from repro.sweep.service import SweepService

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
JSON_PATH = "benchmarks/BENCH_serve.json"
REPS = 5

# child process: time warmup (optional) and the first real request,
# excluding interpreter + jax import (argv[1] is a JSON config)
_CHILD = r"""
import json, sys, time
cfg = json.loads(sys.argv[1])
from repro.sweep.service import SweepService
svc = SweepService(window_ms=0.0)
out = {}
if cfg["warmup"]:
    t0 = time.perf_counter()
    svc.warmup(specs=[cfg["spec_path"]],
               compile_cache_dir=cfg.get("cache_dir"))
    out["warmup_s"] = time.perf_counter() - t0
with open(cfg["spec_path"]) as f:
    doc = json.load(f)
t0 = time.perf_counter()
resp = svc.handle({"spec": doc, "want": ["summary"]})
out["first_request_s"] = time.perf_counter() - t0
out["ok"] = resp["ok"]
svc.close()
print(json.dumps(out))
"""


def _child_run(warmup: bool, cache_dir: str | None = None) -> dict:
    cfg = {"warmup": warmup, "cache_dir": cache_dir,
           "spec_path": os.path.join(ROOT, "specs", "isocap.json")}
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, json.dumps(cfg)],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(f"bench child failed:\n{proc.stderr}")
    out = json.loads(proc.stdout)
    assert out["ok"]
    return out


# -- the concurrent request set ---------------------------------------------


GOLDENS = ("isocap", "dtco", "dtco_isoarea", "lm_nvm")


def _request_docs(copies: int) -> list[dict]:
    """The concurrent request set: every golden spec, ``copies`` clients
    each — the thundering-herd traffic the coalescer exists for.
    Identical in-flight copies collapse to one evaluation (dedup), and
    the distinct same-platform goldens merge through the superset union;
    the serial baseline answers all of them one full evaluation each."""
    docs = []
    for name in GOLDENS:
        with open(os.path.join(ROOT, "specs", f"{name}.json")) as f:
            docs.append(json.load(f))
    return [d for d in docs for _ in range(copies)]


def _fire(svc: SweepService, docs: list[dict],
          want=("summary",)) -> tuple[list[dict], float]:
    # threads are spawned outside the timed region and released together:
    # the clock measures burst-to-last-response wall time only
    barrier = threading.Barrier(len(docs) + 1)
    responses = [None] * len(docs)

    def shoot(i, d):
        barrier.wait()
        responses[i] = svc.handle({"spec": d, "want": list(want)})

    threads = [threading.Thread(target=shoot, args=(i, d))
               for i, d in enumerate(docs)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    assert all(r["ok"] for r in responses), \
        [r.get("error") for r in responses if not r["ok"]]
    return responses, dt


def _serial(svc: SweepService, docs: list[dict]) -> float:
    t0 = time.perf_counter()
    for d in docs:
        resp = svc.handle({"spec": d, "want": ["summary"]})
        assert resp["ok"], resp.get("error")
    return time.perf_counter() - t0


def _parity(responses: list[dict], docs: list[dict]) -> float:
    worst = 0.0
    for d, resp in zip(docs, responses):
        want = sweep.run(SymbolicSweepSpec.from_json(d).resolve()).rows()
        got = resp["rows"]
        assert len(got) == len(want)
        for g, w in zip(got, want):
            for key, wv in w.items():
                gv = g[key]
                if isinstance(wv, float) and wv == wv and wv not in (
                        float("inf"), float("-inf")):
                    err = abs(gv - wv) / (abs(wv) or 1.0)
                    worst = max(worst, err)
                elif not isinstance(wv, float):
                    assert gv == wv
    assert worst <= 1e-12, worst
    return worst


def run(quick: bool = False) -> dict:
    reps = 2 if quick else REPS
    copies = 2 if quick else 8

    # cold start vs warmed first request (fresh process each)
    cold = _child_run(warmup=False)
    warmed = _child_run(warmup=True)
    cache_dir = tempfile.mkdtemp(prefix="deepnvm-jaxcache-")
    warm_hist = {}
    if not quick:
        _child_run(warmup=True, cache_dir=cache_dir)       # populate
        reused = _child_run(warmup=True, cache_dir=cache_dir)
        warm_hist = {"warmup_s_fresh": warmed["warmup_s"],
                     "warmup_s_cached": reused["warmup_s"]}

    # concurrent coalesced vs serial throughput on the golden specs.
    # A near-zero window: a simultaneous burst coalesces through queueing
    # and in-flight dedup (requests pile up while an evaluation is in
    # flight), so the wall clock pays no batching delay.
    docs = _request_docs(copies)
    k = len(docs)
    cells = sum(
        len(SymbolicSweepSpec.from_json(d).resolve().scenarios)
        * len(SymbolicSweepSpec.from_json(d).resolve().designs)
        * len(SymbolicSweepSpec.from_json(d).resolve().platforms)
        for d in docs)                 # requested cells per round

    with SweepService(window_ms=1.0, cache_size=0) as absorb:
        _serial(absorb, docs)          # member + union shapes compile here
        _fire(absorb, docs)

    serial_svc = SweepService(coalesce=False, cache_size=0)
    serial_s = min(_serial(serial_svc, docs) for _ in range(reps))
    serial_svc.close()

    coal_svc = SweepService(window_ms=1.0, cache_size=0)
    coal_s = min(_fire(coal_svc, docs)[1] for _ in range(reps))
    stats = coal_svc.stats()           # before the rows-parity round
    responses, _ = _fire(coal_svc, docs, want=("rows",))
    coalesced = sum(r["source"] == "coalesced" for r in responses)
    worst = _parity(responses, docs)
    coal_stats = coal_svc.stats()["coalesce"]
    coal_svc.close()

    result = dict(
        serve="concurrent sweep service (coalescing + warmup)",
        n_requests=k,
        cells_per_round=cells,
        cold_first_request_s=cold["first_request_s"],
        warm_first_request_s=warmed["first_request_s"],
        warmup_s=warmed["warmup_s"],
        cold_warm_ratio_x=(cold["first_request_s"]
                           / warmed["first_request_s"]),
        **warm_hist,
        serial_s=serial_s,
        coalesced_s=coal_s,
        serial_cells_s=cells / serial_s,
        coalesced_cells_s=cells / coal_s,
        coalesce_speedup_x=serial_s / coal_s,
        requests_s=k / coal_s,
        coalesced_responses=coalesced,
        union_coalesced_requests=coal_stats["coalesced_requests"],
        deduped_requests=coal_stats["deduped_requests"],
        elapsed_ms_p50=stats["elapsed_ms"]["p50"],
        elapsed_ms_p95=stats["elapsed_ms"]["p95"],
        parity_worst_rel_err=worst,
    )
    os.makedirs(os.path.dirname(JSON_PATH), exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)
    return {"rows": [result],
            "bench": {"cold_first_request_s": cold["first_request_s"],
                      "warm_first_request_s": warmed["first_request_s"],
                      "cold_warm_ratio_x": result["cold_warm_ratio_x"],
                      "coalesce_speedup_x": result["coalesce_speedup_x"],
                      "coalesced_cells_s": result["coalesced_cells_s"],
                      "parity_worst_rel_err": worst},
            "derived": (f"cold={cold['first_request_s']:.2f}s,"
                        f"warm={warmed['first_request_s']*1e3:.1f}ms,"
                        f"ratio={result['cold_warm_ratio_x']:.0f}x,"
                        f"coalesce={result['coalesce_speedup_x']:.1f}x,"
                        f"parity_err={worst:.2e}")}


if __name__ == "__main__":
    print(run()["derived"])

"""Paper Fig. 6: DRAM access reduction vs LLC capacity (iso-area).

The curve is one batched [workload] x [capacity] miss-curve evaluation
(workload_engine.dram_tx)."""

from __future__ import annotations

from repro.core import isoarea
from repro.core.calibration import PAPER_CLAIMS


def run() -> dict:
    curve = isoarea.dram_reduction_curve()
    rows = [dict(capacity_mb=c, dram_reduction_pct=v)
            for c, v in curve.items()]
    anchors = PAPER_CLAIMS["isoarea_dram_reduction_pct"]
    checks = {"at_7mb": (curve[7], anchors["stt"]),
              "at_10mb": (curve[10], anchors["sot"])}
    return {"rows": rows, "claims": checks,
            "derived": ",".join(f"{k}={m:.1f}%/(paper {p}%)"
                                for k, (m, p) in checks.items())}

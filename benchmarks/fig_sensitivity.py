"""Sensitivity tables: d ln(EDP) / d ln(device-leaf) elasticities per
(node, tech, scenario) — the inverse subsystem's answer to "which device
knob buys the most at each node".

One differentiable lowering spans the full DTCO node ladder (16/12/10/7
nm, STT and SOT at iso capacity), and ``jacfwd`` through the shared
``engine.ppa_fn`` + workload-fold path prices every leaf at every
(platform, scenario, design point) at once.  An ``elasticity`` of -2
means a 1 % improvement in that leaf buys ~2 % EDP.

Headline (``derived``): the top knob per (node, tech), averaged over
platforms and scenarios.  STT is write-current limited at every node
and increasingly so toward the scaling wall (``ic0_set_a`` elasticity
grows +2.1 at 16 nm -> +3.8 at 7 nm: Ic0 scales worse than the cell,
so its leverage on EDP compounds), while SOT stays sense-path limited
throughout (``sense_time_s`` +0.5 -> +0.7) — the paper's qualitative
cross-layer story, now with signed magnitudes.
"""

from __future__ import annotations

import json
import os
import time

from repro import inverse
from repro.core.sweep import SymbolicSweepSpec
from repro.inverse import sensitivity

JSON_PATH = "benchmarks/BENCH_sensitivity.json"

NODES = ("", "@12nm-scaled", "@10nm-scaled", "@7nm-scaled")
SCENARIOS = (
    "cnn/alexnet/infer@b4",
    "cnn/alexnet/train@b64",
    "cnn/googlenet/infer@b4",
    "cnn/vgg16/train@b64",
    "cnn/resnet18/infer@b4",
    "cnn/resnet18/train@b64",
    "cnn/squeezenet/infer@b4",
    "cnn/squeezenet/train@b64",
)
PLATFORMS = ("gtx-1080ti",)


def _problem(nodes: tuple[str, ...], scenarios: tuple[str, ...],
             ) -> inverse.InverseProblem:
    designs = ["sram@3MB"] + [f"{mem}@3MB{suffix}"
                              for suffix in nodes
                              for mem in ("stt", "sot")]
    doc = {"schema": "deepnvm.sweepspec/2", "name": "sensitivity",
           "scenarios": list(scenarios), "designs": designs,
           "platforms": list(PLATFORMS), "baseline_mem": "sram"}
    return inverse.InverseProblem(
        sweep=SymbolicSweepSpec.from_json(doc), objective="edp",
        area_budget_mm2=None, name="sensitivity")


def run(quick: bool = False) -> dict:
    nodes = NODES[::3] if quick else NODES          # quick: 16 nm + 7 nm
    scenarios = SCENARIOS[:2] if quick else SCENARIOS
    prob = _problem(nodes, scenarios)

    t0 = time.perf_counter()
    rows = sensitivity.sensitivity_rows(prob)
    jac_s = time.perf_counter() - t0
    knobs = sensitivity.top_knobs(rows, n=3)
    top1 = sensitivity.top_knobs(rows, n=1)

    result = dict(
        sensitivity=f"{len(nodes)} nodes x stt/sot x "
                    f"{len(scenarios)} scenarios",
        n_rows=len(rows),
        jacobian_s=jac_s,
        rows_s=len(rows) / jac_s,
        top_knobs=knobs,
    )
    os.makedirs(os.path.dirname(JSON_PATH), exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)

    derived = ";".join(
        f"{k['mem']}@{k['node']}:{k['leaf']}={k['mean_elasticity']:+.2f}"
        for k in top1)
    return {"rows": rows,
            "bench": {"n_rows": len(rows), "jacobian_s": jac_s,
                      "rows_s": result["rows_s"]},
            "derived": derived}


if __name__ == "__main__":
    print(run()["derived"])

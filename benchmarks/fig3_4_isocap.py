"""Paper Figs. 3/4: iso-capacity dynamic/leakage energy and EDP.

Rows are views into one batched [workload-stage] x [memory] fold on the
workload engine (isocap.analyze) — no scalar per-combination calls."""

from __future__ import annotations

from repro.core import isocap
from repro.core.calibration import PAPER_CLAIMS


def run() -> dict:
    rows_ = isocap.analyze()
    summary = isocap.summary(rows_)
    rows = []
    for r in rows_:
        for mem in ("stt", "sot"):
            rows.append(dict(
                workload=r.workload,
                stage="train" if r.training else "infer",
                mem=mem,
                dyn_x=r.norm("dyn", mem),
                leak_x=r.norm("leak", mem),
                energy_x=r.norm("energy", mem),
                edp_x=r.norm("edp", mem, include_dram=True),
                rw_ratio=r.read_write_ratio,
            ))
    claims = PAPER_CLAIMS
    checks = {
        "stt_dyn_x": (summary["stt"]["dyn_energy_x"],
                      claims["isocap_dyn_energy_x"]["stt"]),
        "sot_dyn_x": (summary["sot"]["dyn_energy_x"],
                      claims["isocap_dyn_energy_x"]["sot"]),
        "stt_leak_red": (summary["stt"]["leak_reduction"],
                         claims["isocap_leak_reduction"]["stt"]),
        "sot_leak_red": (summary["sot"]["leak_reduction"],
                         claims["isocap_leak_reduction"]["sot"]),
        "stt_energy_red": (summary["stt"]["energy_reduction"],
                           claims["isocap_energy_reduction"]["stt"]),
        "sot_energy_red": (summary["sot"]["energy_reduction"],
                           claims["isocap_energy_reduction"]["sot"]),
        "stt_edp_red_max": (summary["stt"]["edp_reduction_max"],
                            claims["isocap_edp_reduction_max"]["stt"]),
        "sot_edp_red_max": (summary["sot"]["edp_reduction_max"],
                            claims["isocap_edp_reduction_max"]["sot"]),
        "sram_read_share": (summary["sram"]["read_share_of_dyn"],
                            claims["sram_read_share_of_dyn"]),
    }
    return {"rows": rows, "summary": summary, "claims": checks,
            "derived": ",".join(f"{k}={m:.2f}/(paper {p})"
                                for k, (m, p) in checks.items())}

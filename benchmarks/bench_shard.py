"""Sharded mega-sweep benchmark: cells/sec of the chunked + shard_map
lowering vs chunk size and device count, recorded in
benchmarks/BENCH_shard.json.

Three measurements:

  chunk scan    the full mega spec (repro.scenarios.mega_spec, 1e5+
                cells) through ``run_sharded`` at several (scenario_chunk,
                design_chunk) plans — the knob that trades per-chunk
                compile/dispatch overhead against padded-SoA tensor area.
                The unsharded path is *not* a baseline here: at 182
                scenarios the global-width [s, d, k] fold intermediates
                are multi-GB, which is exactly what the sharded path
                exists to avoid.

  device scan   the same spec with ``ShardPlan(devices=N)`` for N forced
                host devices.  jax fixes its device count at process
                startup, so each point runs in a subprocess with
                ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
                (the worker mode of this module).  Scaling is bounded by
                physical cores — the recorded numbers are honest for the
                machine that ran them.

  parity        sharded-vs-unsharded max relative error on the quick
                spec (small enough to evaluate unsharded), pinned 1e-12.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

JSON_PATH = "benchmarks/BENCH_shard.json"

CHUNK_PLANS = ((4, 16), (8, 32), (16, 96), (64, 288))
DEVICE_COUNTS = (1, 2, 4)

_FIELDS = ("dram_tx", "runtime_s", "runtime_nodram_s", "dyn_read_j",
           "dyn_write_j", "leak_j", "leak_nodram_j", "dram_j")


def _spec(quick: bool):
    from repro import scenarios
    return scenarios.mega_spec(quick=quick)


def _time_plan(spec, plan) -> dict:
    from repro.core import sweep
    t0 = time.perf_counter()
    result = sweep.run_sharded(spec, plan)
    dt = time.perf_counter() - t0
    assert len(result.spec.scenarios) == len(spec.scenarios)
    return {"scenario_chunk": plan.scenario_chunk,
            "design_chunk": plan.design_chunk,
            "devices": plan.devices,
            "n_chunks": len(sweep.split(spec, plan)),
            "seconds": dt,
            "cells_per_s": sweep.n_cells(spec) / dt}


def _parity(quick_spec) -> float:
    from repro.core import sweep
    base = sweep.run(quick_spec)
    res = sweep.run_sharded(
        quick_spec, sweep.ShardPlan(scenario_chunk=7, design_chunk=5,
                                    by_width=True))
    worst = 0.0
    for pi in range(len(quick_spec.platforms)):
        for f in _FIELDS:
            a = getattr(res.tables[pi], f)
            b = getattr(base.tables[pi], f)
            worst = max(worst, float(np.max(
                np.abs(a - b) / np.maximum(np.abs(b), 1e-300))))
    assert worst <= 1e-12, f"sharded parity broke the 1e-12 pin: {worst}"
    return worst


def _worker(devices: int, quick: bool) -> None:
    """Subprocess mode: evaluate the spec on a forced-device-count mesh
    and print one JSON result line (stdout is the IPC channel)."""
    from repro.core import sweep
    spec = _spec(quick)
    plan = sweep.ShardPlan(scenario_chunk=8, design_chunk=32,
                           devices=devices, by_width=True)
    _time_plan(spec, plan)  # warm: jit + design-table lowering
    print(json.dumps(_time_plan(spec, plan)))


def _spawn_worker(devices: int, quick: bool) -> dict:
    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={devices}"
    env["XLA_FLAGS"] = f"{env.get('XLA_FLAGS', '')} {flag}".strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH")) if p)
    cmd = [sys.executable, "-m", "benchmarks.bench_shard",
           "--worker", "--devices", str(devices)] + \
        (["--quick"] if quick else [])
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(quick: bool = False) -> dict:
    from repro.core import sweep
    spec = _spec(quick)
    cells = sweep.n_cells(spec)

    plans = CHUNK_PLANS[1:2] if quick else CHUNK_PLANS
    chunk_scan = []
    for sc, dc in plans:
        plan = sweep.ShardPlan(scenario_chunk=min(sc, len(spec.scenarios)),
                               design_chunk=min(dc, len(spec.designs)),
                               by_width=True)
        chunk_scan.append(_time_plan(spec, plan))

    device_scan = [_spawn_worker(n, quick)
                   for n in (DEVICE_COUNTS[:1] + DEVICE_COUNTS[-1:]
                             if quick else DEVICE_COUNTS)]

    parity = _parity(_spec(quick=True))

    best = max(chunk_scan + device_scan, key=lambda r: r["cells_per_s"])
    result = dict(
        shard="chunked + shard_map sweep lowering",
        spec=spec.name, cells=cells,
        chunk_scan=chunk_scan, device_scan=device_scan,
        parity_max_rel_err=parity,
        best_cells_per_s=best["cells_per_s"])
    os.makedirs(os.path.dirname(JSON_PATH), exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(result, f, indent=2)

    flat_rows = [dict(kind="chunk", **r) for r in chunk_scan] + \
        [dict(kind="device", **r) for r in device_scan]
    scale = (device_scan[-1]["cells_per_s"] / device_scan[0]["cells_per_s"]
             if device_scan else float("nan"))
    return {"rows": flat_rows,
            "bench": {"cells": cells,
                      "best_cells_per_s": best["cells_per_s"],
                      "device_scale_x": scale,
                      "parity_max_rel_err": parity},
            "derived": (f"cells={cells},"
                        f"best={best['cells_per_s']:,.0f}/s,"
                        f"dev{device_scan[0]['devices']}->"
                        f"{device_scan[-1]['devices']}={scale:.2f}x,"
                        f"parity_err={parity:.2e}")}


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--worker", action="store_true",
                    help="internal: single device-count measurement")
    ap.add_argument("--devices", type=int, default=1)
    args = ap.parse_args(argv)
    if args.worker:
        _worker(args.devices, args.quick)
    else:
        print(run(quick=args.quick)["derived"])


if __name__ == "__main__":
    main()

"""End-to-end training: a ~100M-param TinyLlama-family model for a few
hundred steps on the host mesh, with checkpointing and fault injection.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import dataclasses

import repro.configs as configs
from repro.launch import train

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=300)
p.add_argument("--fault-at", type=int, default=None)
args = p.parse_args()

# ~100M params: 12 x 512 llama-family with the tinyllama vocab
base = configs.get("tinyllama-1.1b")
cfg = dataclasses.replace(base, name="tinyllama-100m", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4,
                          head_dim=64, d_ff=2048)
print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")

argv = ["--arch", "tinyllama-1.1b", "--steps", str(args.steps),
        "--batch", "8", "--seq", "256", "--ckpt-dir", "runs/ckpt_100m"]
if args.fault_at is not None:
    argv += ["--fault-at", str(args.fault_at)]

# monkeypatch config resolution so the driver builds the 100M variant
configs_get = configs.get
configs.get = lambda name, reduced=False: cfg  # noqa: E731
try:
    losses = train.main(argv)
finally:
    configs.get = configs_get
assert losses[-1] < losses[0], "loss did not decrease"
print("OK: loss decreased", losses[0], "->", losses[-1])

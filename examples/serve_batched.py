"""Batched serving example: prefill + KV-cache decode on a reduced config.

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve

serve.main(["--arch", "qwen3-14b", "--reduced", "--batch", "4",
            "--prompt-len", "32", "--gen", "16"])

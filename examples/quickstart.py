"""Quickstart: the DeepNVM++ pipeline end-to-end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. characterize bitcells (paper Table I),
2. EDAP-tune caches at 3 MB (paper Table II / Algorithm 1),
3. fold a DL workload's memory behavior through the models (paper Fig. 4),
4. ask the paper's question for one assigned LM arch on the TPU target.
"""
from repro.core import bitcell, isocap, traffic, tuner
from repro.core.workloads import alexnet

# 1. circuit layer
for name, cell in bitcell.table1().items():
    print(f"{name}: write {cell.write_latency_avg_s*1e9:.2f} ns "
          f"{cell.write_energy_avg_j*1e12:.2f} pJ area {cell.area_norm}x")

# 2. microarchitecture layer (Algorithm 1)
designs = {m: tuner.tuned_design(m, 3) for m in ("sram", "stt", "sot")}
for m, d in designs.items():
    print(f"{m}: rd {d.read_latency_s*1e9:.2f} ns, leak {d.leakage_w:.2f} W, "
          f"area {d.area_mm2:.2f} mm2 [{d.org}]")

# 3. architecture layer: AlexNet inference on the 1080 Ti calibration target
stats = traffic.build(alexnet(), batch=4, training=False)
for m, d in designs.items():
    rep = traffic.energy(stats, d)
    print(f"{m}: E {rep.total_j(False)*1e3:.1f} mJ, EDP "
          f"{rep.edp(True)*1e6:.2f} mJ*ms")

# 4. the same question for an assigned LM architecture on TPU-class HW
import os  # noqa: E402  (repo root onto sys.path for benchmarks.lm_nvm)
import sys  # noqa: E402
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks.lm_nvm import lm_traffic  # noqa: E402
from repro.core.tech import TPU_V5E  # noqa: E402
designs48 = {m: tuner.tuned_design(m, 48) for m in ("sram", "stt", "sot")}
lm_stats = lm_traffic("tinyllama-1.1b", "decode_32k")
base = traffic.energy(lm_stats, designs48["sram"], TPU_V5E)
for m in ("stt", "sot"):
    rep = traffic.energy(lm_stats, designs48[m], TPU_V5E)
    print(f"tinyllama decode_32k, {m} 48MB buffer: "
          f"EDP reduction {base.edp(True)/rep.edp(True):.1f}x")

"""Quickstart: the DeepNVM++ pipeline end-to-end in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. characterize bitcells (paper Table I),
2. EDAP-tune caches at 3 MB (paper Table II / Algorithm 1),
3. fold a DL workload's memory behavior through the models (paper Fig. 4),
4. ask the paper's question for one assigned LM arch on the TPU target.
"""
from repro.core import bitcell, traffic, tuner
from repro.core.workloads import alexnet

# 1. circuit layer
for name, cell in bitcell.table1().items():
    print(f"{name}: write {cell.write_latency_avg_s*1e9:.2f} ns "
          f"{cell.write_energy_avg_j*1e12:.2f} pJ area {cell.area_norm}x")

# 2. microarchitecture layer (Algorithm 1)
designs = {m: tuner.tuned_design(m, 3) for m in ("sram", "stt", "sot")}
for m, d in designs.items():
    print(f"{m}: rd {d.read_latency_s*1e9:.2f} ns, leak {d.leakage_w:.2f} W, "
          f"area {d.area_mm2:.2f} mm2 [{d.org}]")

# 3. architecture layer: AlexNet inference on the 1080 Ti calibration target
stats = traffic.build(alexnet(), batch=4, training=False)
for m, d in designs.items():
    rep = traffic.energy(stats, d)
    print(f"{m}: E {rep.total_j(False)*1e3:.1f} mJ, EDP "
          f"{rep.edp(True)*1e6:.2f} mJ*ms")

# 4. the same question for an assigned LM architecture on TPU-class HW,
#    as one declarative sweep (scenario registry + unified pipeline)
from repro import scenarios  # noqa: E402
from repro.core import sweep  # noqa: E402
from repro.core.tech import TPU_V5E  # noqa: E402
res = sweep.run(scenarios.lm_sweep_spec(
    archs=("tinyllama-1.1b",), shapes=("decode_32k",),
    platforms=(TPU_V5E,)))
edp_x = res.norm_to().metric("edp", include_dram=True)
for m in ("stt", "sot"):
    print(f"tinyllama decode_32k, {m} 48MB buffer: "
          f"EDP reduction {1 / edp_x[0, 0, res.design_index(m)]:.1f}x")

# 5. the same sweep as a serializable document (SweepSpec v2): names
#    resolved through the registries, sharing the memoized result above —
#    this JSON is exactly what `python -m repro.sweep run spec.json` takes
sym = sweep.SymbolicSweepSpec(
    scenarios=("lm/tinyllama-1.1b/decode_32k",),
    designs=("sram@48MB", "stt@48MB", "sot@48MB"),
    platforms=("tpu-v5e",), name="lm-nvm")
assert sym.run() is res  # same registries, same memo, zero re-evaluation
print("\nsymbolic form:\n" + sym.to_json())

"""Design-space exploration with DeepNVM++ (the paper's framework claim):
sweep technology x capacity x workload x platform — and, for the DTCO
section, x technology node — and emit the EDP landscape.

The whole pipeline is one declarative SweepSpec: it lowers to a single
circuit-engine evaluation of every (node x tech x capacity x organization)
design point plus a single workload-engine fold of every workload through
every tuned design on every platform.

    PYTHONPATH=src python examples/nvm_dse.py
"""
from repro.core import dtco, sweep
from repro.core.report import markdown_table
from repro.core.tech import GTX_1080TI, TPU_V5E
from repro.core.workloads import paper_workloads

CAPS_MB = (2, 3, 6, 12, 24)

spec = sweep.SweepSpec(
    name="nvm-dse",
    scenarios=sweep.workload_scenarios(paper_workloads(), ((False, 4),)),
    designs=sweep.design_grid(sweep.MEMS, CAPS_MB),
    platforms=(GTX_1080TI, TPU_V5E),
)
res = sweep.run(spec)

# normalized EDP per (platform, workload, design), baseline = SRAM of the
# same capacity group; the query layer slices the labeled axes directly
rows = [dict(platform=r["platform"], capacity_mb=r["capacity_mb"],
             workload=r["workload"], mem=r["mem"],
             edp_reduction=round(1.0 / r["edp_x"], 2))
        for r in res.filter(mem=("stt", "sot")).rows(include_dram=True)]
print(markdown_table(rows))
best = max(rows, key=lambda r: r["edp_reduction"])
print("\nbest design point:", best)

# -- DSE reductions: Pareto fronts + capacity plateaus -----------------------
# Non-dominated (energy, runtime, area) designs per scenario, and the
# capacity beyond which growing the cache buys < 5% EDP.
front = res.pareto_front()
print(f"\npareto front (energy/runtime/area): {len(front)} of "
      f"{len(res.rows())} rows survive; alexnet×gtx front:")
print(markdown_table(
    [{k: r[k] for k in ("mem", "capacity_mb", "energy", "runtime", "area")}
     for r in front
     if r["platform"] == "gtx-1080ti" and r["workload"] == "alexnet"]))
plateaus = [p for p in res.capacity_plateaus()
            if p["platform"] == "gtx-1080ti" and p["workload"] == "alexnet"]
print("\ncapacity plateaus (alexnet, EDP within 5% of best):")
print(markdown_table([{k: p[k] for k in ("mem", "plateau_capacity_mb",
                                         "best_capacity_mb")}
                      for p in plateaus]))

# -- cross-node DTCO: the node as one more batched axis ----------------------
# One design_table call covers 16/12/10/7 nm; every node is normalized to
# its own SRAM baseline (the per-node comparison DTCO studies make).
trend = dtco.analyze(capacity_mb=3)
print("\ncross-node iso-capacity trend (3 MB, GTX 1080 Ti workloads):")
print(markdown_table([dict(node=r.node, mem=r.mem,
                           leakage_w=round(r.leakage_w, 3),
                           leak_x=round(r.leak_x, 4),
                           edp_x=round(r.edp_x, 4))
                      for r in trend]))
head = dtco.headline(trend)
print(f"\nSRAM leakage {head['sram']['leak_w_first']:.2f} W @16nm -> "
      f"{head['sram']['leak_w_last']:.2f} W @7nm "
      f"(x{head['sram']['leak_growth']:.2f}); "
      f"SOT EDP reduction {head['sot']['edp_reduction_first']:.2f}x @16nm -> "
      f"{head['sot']['edp_reduction_last']:.2f}x @7nm")

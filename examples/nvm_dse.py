"""Design-space exploration with DeepNVM++ (the paper's framework claim):
sweep technology x capacity x workload and emit the EDP landscape.

    PYTHONPATH=src python examples/nvm_dse.py
"""
from repro.core import scaling, traffic, tuner
from repro.core.report import markdown_table
from repro.core.workloads import paper_workloads

rows = []
for cap in (2, 3, 6, 12, 24):
    designs = {m: tuner.tuned_design(m, cap) for m in ("sram", "stt", "sot")}
    for wname, w in paper_workloads().items():
        stats = traffic.build(w, batch=4, training=False)
        base = traffic.energy(stats, designs["sram"])
        for m in ("stt", "sot"):
            rep = traffic.energy(stats, designs[m])
            rows.append(dict(capacity_mb=cap, workload=wname, mem=m,
                             edp_reduction=round(
                                 base.edp(True) / rep.edp(True), 2)))
print(markdown_table(rows))
best = max(rows, key=lambda r: r["edp_reduction"])
print("\nbest design point:", best)

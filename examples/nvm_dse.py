"""Design-space exploration with DeepNVM++ (the paper's framework claim):
sweep technology x capacity x workload and emit the EDP landscape.

    PYTHONPATH=src python examples/nvm_dse.py
"""
from repro.core import engine, traffic
from repro.core.report import markdown_table
from repro.core.workloads import paper_workloads

CAPS_MB = (2, 3, 6, 12, 24)
MEMS = ("sram", "stt", "sot")

# the whole (tech x capacity x organization) space, one batched evaluation
table = engine.design_table(MEMS, tuple(c * 2**20 for c in CAPS_MB))

rows = []
for cap in CAPS_MB:
    designs = {m: table.tuned(m, cap * 2**20) for m in MEMS}
    for wname, w in paper_workloads().items():
        stats = traffic.build(w, batch=4, training=False)
        base = traffic.energy(stats, designs["sram"])
        for m in ("stt", "sot"):
            rep = traffic.energy(stats, designs[m])
            rows.append(dict(capacity_mb=cap, workload=wname, mem=m,
                             edp_reduction=round(
                                 base.edp(True) / rep.edp(True), 2)))
print(markdown_table(rows))
best = max(rows, key=lambda r: r["edp_reduction"])
print("\nbest design point:", best)

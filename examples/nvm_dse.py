"""Design-space exploration with DeepNVM++ (the paper's framework claim):
sweep technology x capacity x workload and emit the EDP landscape.

The whole pipeline is two composed batched computations: the circuit
engine evaluates every (tech x capacity x organization) design point in
one jitted call, and the workload engine folds every workload through
every tuned (tech, capacity) design in a second one.

    PYTHONPATH=src python examples/nvm_dse.py
"""
from repro.core import engine, workload_engine
from repro.core.report import markdown_table
from repro.core.workloads import paper_workloads

CAPS_MB = (2, 3, 6, 12, 24)
MEMS = ("sram", "stt", "sot")

# the whole (tech x capacity x organization) space, one batched evaluation
table = engine.design_table(MEMS, tuple(c * 2**20 for c in CAPS_MB))
designs = tuple(table.tuned(m, cap * 2**20) for cap in CAPS_MB for m in MEMS)

# every (workload x design) EDP, one batched workload-engine evaluation
stats = [workload_engine.stats_for(w, 4, False)
         for w in paper_workloads().values()]
wt = workload_engine.evaluate(stats, designs)
edp = wt.edp(include_dram=True)  # [workload, design]

rows = []
for ci, cap in enumerate(CAPS_MB):
    base = ci * len(MEMS)  # sram column of this capacity
    for si, (wname, _, _) in enumerate(wt.scenarios):
        for mi, m in enumerate(MEMS[1:], start=1):
            rows.append(dict(capacity_mb=cap, workload=wname, mem=m,
                             edp_reduction=round(
                                 float(edp[si, base] / edp[si, base + mi]),
                                 2)))
print(markdown_table(rows))
best = max(rows, key=lambda r: r["edp_reduction"])
print("\nbest design point:", best)
